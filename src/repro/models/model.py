"""Model assembly: init / forward / loss / prefill / decode for all families.

Layer stacks are ``jax.lax.scan``-ed over stacked params so HLO size and
compile time are depth-independent; heterogeneous archs scan *super-blocks*:

  family      segments
  ----------  -----------------------------------------------------------
  dense       [stack: attn_mlp × L]                (qwen3, stablelm, internvl2 LM)
  gemma       [super: (5×local + 1×global) × L//6, rem: local × (L mod 6)]
  moe         [dense0 × n_dense (unrolled), stack: attn_moe × (L - n_dense)]
  ssm         [stack: ssm × L]                     (mamba2)
  zamba       [super: (6×ssm + shared attn_mlp) × L//6, rem: ssm × (L mod 6)]
  whisper     encoder [enc × L_enc] + decoder [cross × L]

Caches mirror the param tree; decode positions are per-sequence ``(B,)``
(continuous batching decodes ragged slots in lockstep HLO).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import checkpoint_policies as _cp


def _remat(fn, policy: str):
    if policy == "collectives":
        return jax.checkpoint(fn, policy=_cp.save_only_these_names(
            "attn_out", "mlp_out", "moe_out", "ssm_out"))
    return jax.checkpoint(fn)

from repro.configs.base import ArchConfig
from repro.models import blocks
from repro.models.layers import (constrain_batch, embed_fwd, init_embed,
                                 init_linear, init_norm, linear_fwd,
                                 norm_fwd, truncated_normal)

Params = Any
Cache = Any


def family(cfg: ArchConfig) -> str:
    if cfg.arch_type == "audio":
        return "whisper"
    if cfg.arch_type == "hybrid":
        return "zamba"
    if cfg.arch_type == "ssm":
        return "ssm"
    if cfg.is_moe:
        return "moe"
    if cfg.local_global_pattern:
        return "gemma"
    return "dense"  # incl. vlm (vision prefix handled at embed time)


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    out = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    if d % 2:
        out = jnp.pad(out, [(0, 0)] * (out.ndim - 1) + [(0, 1)])
    return out


def _stacked_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


class Model:
    """Functional model wrapper: all methods are pure (jit/pjit friendly)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.fam = family(cfg)
        if self.fam in ("gemma", "zamba"):
            per = (cfg.local_global_pattern + 1 if self.fam == "gemma"
                   else cfg.shared_attn_every)
            self.super_len = per
            self.n_super = cfg.n_layers // per
            self.n_rem = cfg.n_layers - self.n_super * per
        elif self.fam == "moe":
            self.n_dense = cfg.n_dense_layers
            self.n_moe = cfg.n_layers - self.n_dense

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        p: dict = {"embed": init_embed(keys[0], cfg.vocab_size, cfg.d_model,
                                       dtype),
                   "final_norm": init_norm(cfg, cfg.d_model, dtype)}
        if not cfg.tie_embeddings:
            p["lm_head"] = init_linear(keys[1], cfg.d_model, cfg.vocab_size,
                                       dtype)
        if cfg.n_vision_tokens:
            p["vis_proj"] = {
                "w1": init_linear(keys[2], cfg.vision_embed_dim, cfg.d_model,
                                  dtype),
                "w2": init_linear(keys[3], cfg.d_model, cfg.d_model, dtype),
            }
        fam = self.fam
        if fam == "dense":
            p["stack"] = _stacked_init(
                lambda k: blocks.init_attn_mlp(k, cfg, dtype), keys[4],
                cfg.n_layers)
        elif fam == "gemma":
            def init_super(k):
                kl, kg = jax.random.split(k)
                return {
                    "local": _stacked_init(
                        lambda kk: blocks.init_attn_mlp(kk, cfg, dtype), kl,
                        self.super_len - 1),
                    "global": blocks.init_attn_mlp(kg, cfg, dtype),
                }
            p["super"] = _stacked_init(init_super, keys[4], self.n_super)
            if self.n_rem:
                p["rem"] = _stacked_init(
                    lambda k: blocks.init_attn_mlp(k, cfg, dtype), keys[5],
                    self.n_rem)
        elif fam == "moe":
            if self.n_dense:
                p["dense0"] = _stacked_init(
                    lambda k: blocks.init_attn_mlp(k, cfg, dtype), keys[5],
                    self.n_dense)
            p["stack"] = _stacked_init(
                lambda k: blocks.init_attn_moe(k, cfg, dtype), keys[4],
                self.n_moe)
        elif fam == "ssm":
            p["stack"] = _stacked_init(
                lambda k: blocks.init_ssm_block(k, cfg, dtype), keys[4],
                cfg.n_layers)
        elif fam == "zamba":
            def init_super(k):
                return {"ssm": _stacked_init(
                    lambda kk: blocks.init_ssm_block(kk, cfg, dtype), k,
                    self.super_len)}
            p["super"] = _stacked_init(init_super, keys[4], self.n_super)
            p["shared"] = blocks.init_attn_mlp(keys[5], cfg, dtype,
                                               use_mla=False)
            if self.n_rem:
                p["rem"] = _stacked_init(
                    lambda k: blocks.init_ssm_block(k, cfg, dtype), keys[6],
                    self.n_rem)
        elif fam == "whisper":
            p["enc_stack"] = _stacked_init(
                lambda k: blocks.init_encoder_block(k, cfg, dtype), keys[4],
                cfg.n_encoder_layers)
            p["enc_norm"] = init_norm(cfg, cfg.d_model, dtype)
            p["stack"] = _stacked_init(
                lambda k: blocks.init_cross_block(k, cfg, dtype), keys[5],
                cfg.n_layers)
        else:
            raise ValueError(fam)
        return p

    # ------------------------------------------------------------------
    # embedding / head
    # ------------------------------------------------------------------
    def _embed(self, params: Params, batch: dict) -> jax.Array:
        cfg = self.cfg
        x = embed_fwd(params["embed"], batch["tokens"])
        if cfg.n_vision_tokens:
            v = linear_fwd(params["vis_proj"]["w1"], batch["vision_embeds"])
            v = jax.nn.gelu(v)
            v = linear_fwd(params["vis_proj"]["w2"], v).astype(x.dtype)
            x = jnp.concatenate([v, x], axis=1)
        if cfg.pos_embed == "learned":  # sinusoidal absolute (whisper)
            S = x.shape[1]
            x = x + _sinusoid(jnp.arange(S), cfg.d_model).astype(x.dtype)
        return constrain_batch(x)

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        x = norm_fwd(self.cfg, params["final_norm"], x)
        if self.cfg.tie_embeddings:
            return jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
        return linear_fwd(params["lm_head"], x)

    def _encode(self, params: Params, batch: dict) -> jax.Array:
        cfg = self.cfg
        frames = batch["audio_frames"]
        x = frames + _sinusoid(jnp.arange(frames.shape[1]),
                               cfg.d_model).astype(frames.dtype)

        def step(carry, p):
            return blocks.encoder_fwd(p, cfg, carry), None

        x, _ = jax.lax.scan(step, x, params["enc_stack"])
        return norm_fwd(cfg, params["enc_norm"], x)

    # ------------------------------------------------------------------
    # full forward (training / eval)
    # ------------------------------------------------------------------
    def forward(self, params: Params, batch: dict, remat: bool = False,
                train: bool = False,
                remat_policy: str = "none") -> tuple[jax.Array, jax.Array]:
        """Returns (logits, moe_aux_loss). ``remat_policy="collectives"``
        saves the per-block attention/MLP/MoE/SSM outputs (checkpoint_name
        markers in blocks.py) so the backward pass does NOT recompute the
        row-parallel all-reduces — §Perf iteration: trades ~2 activations/
        layer of HBM for a third of the collective wire."""
        cfg, fam = self.cfg, self.fam
        aux = jnp.zeros((), jnp.float32)
        if fam == "whisper":
            memory = self._encode(params, batch)
            x = self._embed(params, batch)

            def step(carry, p):
                return blocks.cross_fwd(p, cfg, carry, memory), None

            body = _remat(step, remat_policy) if remat else step
            x, _ = jax.lax.scan(body, x, params["stack"])
            return self._head(params, x), aux

        x = self._embed(params, batch)
        if fam == "dense":
            def step(carry, p):
                return blocks.attn_mlp_fwd(p, cfg, carry,
                                           window=cfg.sliding_window), None
            body = _remat(step, remat_policy) if remat else step
            x, _ = jax.lax.scan(body, x, params["stack"])
        elif fam == "gemma":
            def super_step(carry, p):
                def local_step(c, pl_):
                    return blocks.attn_mlp_fwd(
                        pl_, cfg, c, window=cfg.sliding_window), None
                c, _ = jax.lax.scan(local_step, carry, p["local"])
                c = blocks.attn_mlp_fwd(p["global"], cfg, c, window=0)
                return c, None
            body = _remat(super_step, remat_policy) if remat else super_step
            x, _ = jax.lax.scan(body, x, params["super"])
            if self.n_rem:
                def rem_step(c, pl_):
                    return blocks.attn_mlp_fwd(
                        pl_, cfg, c, window=cfg.sliding_window), None
                x, _ = jax.lax.scan(rem_step, x, params["rem"])
        elif fam == "moe":
            if self.n_dense:
                def d_step(carry, p):
                    return blocks.attn_mlp_fwd(
                        p, cfg, carry, window=cfg.sliding_window), None
                x, _ = jax.lax.scan(d_step, x, params["dense0"])

            def m_step(carry, p):
                h, a = carry
                h, ax = blocks.attn_moe_fwd(p, cfg, h,
                                            window=cfg.sliding_window,
                                            train=train)
                return (h, a + ax), None
            body = _remat(m_step, remat_policy) if remat else m_step
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["stack"])
        elif fam == "ssm":
            def step(carry, p):
                return blocks.ssm_fwd(p, cfg, carry), None
            body = _remat(step, remat_policy) if remat else step
            x, _ = jax.lax.scan(body, x, params["stack"])
        elif fam == "zamba":
            shared = params["shared"]

            def super_step(carry, p):
                def s_step(c, ps):
                    return blocks.ssm_fwd(ps, cfg, c), None
                c, _ = jax.lax.scan(s_step, carry, p["ssm"])
                c = blocks.attn_mlp_fwd(shared, cfg, c, window=0)
                return c, None
            body = _remat(super_step, remat_policy) if remat else super_step
            x, _ = jax.lax.scan(body, x, params["super"])
            if self.n_rem:
                def r_step(c, ps):
                    return blocks.ssm_fwd(ps, cfg, c), None
                x, _ = jax.lax.scan(r_step, x, params["rem"])
        else:
            raise ValueError(fam)
        return self._head(params, x), aux

    # ------------------------------------------------------------------
    # loss
    # ------------------------------------------------------------------
    def loss(self, params: Params, batch: dict, remat: bool = False,
             remat_policy: str = "none") -> tuple[jax.Array, dict]:
        cfg = self.cfg
        logits, aux = self.forward(params, batch, remat=remat, train=True,
                                   remat_policy=remat_policy)
        if cfg.n_vision_tokens:
            logits = logits[:, cfg.n_vision_tokens:]
        tokens = batch["tokens"]
        tgt = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        nll = logz - gold
        mask = (batch["loss_mask"][:, 1:] if "loss_mask" in batch
                else jnp.ones_like(tgt)).astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = loss + cfg.router_aux_loss_coef * aux
        return total, {"nll": loss, "moe_aux": aux,
                       "tokens": jnp.sum(mask)}

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def _stack_zeros(self, proto, n: int):
        # replicate the proto across a layer axis. Dense protos are
        # zero-filled so this equals stacking zeros; paged block tables
        # must keep their scratch-page fill, which plain zeros would
        # silently turn into "everyone shares physical page 0".
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), proto)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32,
                   layout=None) -> Cache:
        """``layout`` (a models.cache.PagedLayout) switches every pageable
        layer group to the block/paged cache; None keeps the dense rows."""
        cfg, fam = self.cfg, self.fam
        mk = functools.partial(blocks.init_block_cache, cfg, batch=batch,
                               max_len=max_len, dtype=dtype, layout=layout)
        if fam == "dense":
            return {"stack": self._stack_zeros(
                mk("attn", window=cfg.sliding_window), cfg.n_layers)}
        if fam == "gemma":
            local = mk("attn", window=cfg.sliding_window)
            glob = mk("attn", window=0)
            c = {"super": {
                "local": self._stack_zeros(
                    self._stack_zeros(local, self.super_len - 1), self.n_super),
                "global": self._stack_zeros(glob, self.n_super)}}
            if self.n_rem:
                c["rem"] = self._stack_zeros(local, self.n_rem)
            return c
        if fam == "moe":
            kind = "mla" if cfg.mla else "attn"
            c = {"stack": self._stack_zeros(
                mk(kind, window=cfg.sliding_window), self.n_moe)}
            if self.n_dense:
                c["dense0"] = self._stack_zeros(
                    mk(kind, window=cfg.sliding_window), self.n_dense)
            return c
        if fam == "ssm":
            return {"stack": self._stack_zeros(mk("ssm"), cfg.n_layers)}
        if fam == "zamba":
            c = {"super": {
                "ssm": self._stack_zeros(
                    self._stack_zeros(mk("ssm"), self.super_len), self.n_super),
                "shared": self._stack_zeros(mk("attn"), self.n_super)}}
            if self.n_rem:
                c["rem"] = self._stack_zeros(mk("ssm"), self.n_rem)
            return c
        if fam == "whisper":
            return {"stack": self._stack_zeros(mk("cross"), cfg.n_layers)}
        raise ValueError(fam)

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    @staticmethod
    def _sel(x: jax.Array, logits_at) -> jax.Array:
        """Select the hidden state the head runs on: a shared position
        (int) or one position per sequence ((B,) array — bucket-batched
        prefill, where same-bucket prompts have different real lengths)."""
        if isinstance(logits_at, int):
            return x[:, logits_at]
        idx = jnp.asarray(logits_at, jnp.int32)
        return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]

    def prefill(self, params: Params, batch: dict, cache: Cache,
                logits_at: int | jax.Array = -1) -> tuple[jax.Array, Cache]:
        """Returns (logits (B, V) at ``logits_at``, filled cache); serving
        passes the last *real* (pre-padding) prompt position — scalar, or
        per-sequence (B,) when a padded bucket batches ragged prompts."""
        cfg, fam = self.cfg, self.fam
        if fam == "whisper":
            memory = self._encode(params, batch)
            x = self._embed(params, batch)

            def step(carry, pc):
                p, c = pc
                h, nc = blocks.cross_prefill(p, cfg, carry, memory, c)
                return h, nc

            x, ncache = jax.lax.scan(step, x, (params["stack"],
                                               cache["stack"]))
            return self._head(params, self._sel(x, logits_at)), {"stack": ncache}

        x = self._embed(params, batch)
        new_cache: dict = {}
        if fam in ("dense", "moe"):
            if fam == "moe" and self.n_dense:
                def d_step(carry, pc):
                    p, c = pc
                    h, nc = blocks.attn_mlp_prefill(
                        p, cfg, carry, c, window=cfg.sliding_window)
                    return h, nc
                x, nd = jax.lax.scan(d_step, x, (params["dense0"],
                                                 cache["dense0"]))
                new_cache["dense0"] = nd
            fwd = (blocks.attn_moe_prefill if fam == "moe"
                   else blocks.attn_mlp_prefill)

            def step(carry, pc):
                p, c = pc
                h, nc = fwd(p, cfg, carry, c, window=cfg.sliding_window)
                return h, nc
            x, ns = jax.lax.scan(step, x, (params["stack"], cache["stack"]))
            new_cache["stack"] = ns
        elif fam == "gemma":
            def super_step(carry, pc):
                p, c = pc

                def l_step(cc, plc):
                    pl_, cl = plc
                    h, nc = blocks.attn_mlp_prefill(
                        pl_, cfg, cc, cl, window=cfg.sliding_window)
                    return h, nc
                h, nl = jax.lax.scan(l_step, carry, (p["local"], c["local"]))
                h, ng = blocks.attn_mlp_prefill(p["global"], cfg, h,
                                                c["global"], window=0)
                return h, {"local": nl, "global": ng}
            x, nsuper = jax.lax.scan(super_step, x,
                                     (params["super"], cache["super"]))
            new_cache["super"] = nsuper
            if self.n_rem:
                def r_step(cc, plc):
                    pl_, cl = plc
                    h, nc = blocks.attn_mlp_prefill(
                        pl_, cfg, cc, cl, window=cfg.sliding_window)
                    return h, nc
                x, nr = jax.lax.scan(r_step, x, (params["rem"], cache["rem"]))
                new_cache["rem"] = nr
        elif fam == "ssm":
            def step(carry, p):
                return blocks.ssm_prefill(p, cfg, carry)
            x, ns = jax.lax.scan(step, x, params["stack"])
            new_cache["stack"] = ns
        elif fam == "zamba":
            shared = params["shared"]

            def super_step(carry, pc):
                p, c = pc

                def s_step(cc, ps):
                    return blocks.ssm_prefill(ps, cfg, cc)
                h, nssm = jax.lax.scan(s_step, carry, p["ssm"])
                h, nsh = blocks.attn_mlp_prefill(shared, cfg, h, c["shared"],
                                                 window=0)
                return h, {"ssm": nssm, "shared": nsh}
            x, nsuper = jax.lax.scan(super_step, x,
                                     (params["super"], cache["super"]))
            new_cache["super"] = nsuper
            if self.n_rem:
                def r_step(cc, ps):
                    return blocks.ssm_prefill(ps, cfg, cc)
                x, nr = jax.lax.scan(r_step, x, params["rem"])
                new_cache["rem"] = nr
        else:
            raise ValueError(fam)
        return self._head(params, self._sel(x, logits_at)), new_cache

    def prefill_suffix(self, params: Params, batch: dict, cache: Cache,
                       ctx: dict, offset: int,
                       logits_at: int | jax.Array = -1
                       ) -> tuple[jax.Array, Cache]:
        """Prefill only the residual suffix of prompts whose first
        ``offset`` positions are prefix-cache hits: ``ctx`` mirrors the
        cache tree with per-group ``{"k", "v"}`` context of width exactly
        ``offset`` (gathered from the shared pages), ``batch["tokens"]``
        holds the suffix tokens, and the returned mini-cache covers the
        suffix positions only (``insert`` lands it at ``offset``).
        Restricted to the sharing-eligible families — dense/moe, non-MLA,
        full-horizon rope attention, text-only suffix (the engine's gate;
        vision/audio prefixes are inside the shared ``offset``)."""
        cfg, fam = self.cfg, self.fam
        if fam not in ("dense", "moe") or cfg.mla:
            raise ValueError(f"prefix sharing unsupported for {fam}")
        x = constrain_batch(embed_fwd(params["embed"], batch["tokens"]))
        new_cache: dict = {}
        if fam == "moe" and self.n_dense:
            def d_step(carry, pcc):
                p, c, ck, cv = pcc
                h, nc = blocks.attn_mlp_suffix_prefill(p, cfg, carry, c,
                                                       ck, cv, offset)
                return h, nc
            x, nd = jax.lax.scan(d_step, x, (params["dense0"],
                                             cache["dense0"],
                                             ctx["dense0"]["k"],
                                             ctx["dense0"]["v"]))
            new_cache["dense0"] = nd
        fwd = (blocks.attn_moe_suffix_prefill if fam == "moe"
               else blocks.attn_mlp_suffix_prefill)

        def step(carry, pcc):
            p, c, ck, cv = pcc
            h, nc = fwd(p, cfg, carry, c, ck, cv, offset)
            return h, nc
        x, ns = jax.lax.scan(step, x, (params["stack"], cache["stack"],
                                       ctx["stack"]["k"],
                                       ctx["stack"]["v"]))
        new_cache["stack"] = ns
        return self._head(params, self._sel(x, logits_at)), new_cache

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def decode_step(self, params: Params, tokens: jax.Array, cache: Cache,
                    pos: jax.Array) -> tuple[jax.Array, Cache]:
        """tokens: (B, 1); pos: (B,) current absolute positions.
        Returns (logits (B, V), new cache)."""
        cfg, fam = self.cfg, self.fam
        x = embed_fwd(params["embed"], tokens)
        if cfg.pos_embed == "learned":
            x = x + _sinusoid(pos[:, None], cfg.d_model).astype(x.dtype)
        new_cache: dict = {}
        if fam == "whisper":
            def step(carry, pc):
                p, c = pc
                h, nc = blocks.cross_decode(p, cfg, carry, c, pos)
                return h, nc
            x, ns = jax.lax.scan(step, x, (params["stack"], cache["stack"]))
            return self._head(params, x[:, -1]), {"stack": ns}

        if fam in ("dense", "moe"):
            if fam == "moe" and self.n_dense:
                def d_step(carry, pc):
                    p, c = pc
                    h, nc = blocks.attn_mlp_decode(p, cfg, carry, c, pos)
                    return h, nc
                x, nd = jax.lax.scan(d_step, x, (params["dense0"],
                                                 cache["dense0"]))
                new_cache["dense0"] = nd
            fwd = (blocks.attn_moe_decode if fam == "moe"
                   else blocks.attn_mlp_decode)

            def step(carry, pc):
                p, c = pc
                h, nc = fwd(p, cfg, carry, c, pos)
                return h, nc
            x, ns = jax.lax.scan(step, x, (params["stack"], cache["stack"]))
            new_cache["stack"] = ns
        elif fam == "gemma":
            def super_step(carry, pc):
                p, c = pc

                def l_step(cc, plc):
                    pl_, cl = plc
                    return blocks.attn_mlp_decode(pl_, cfg, cc, cl, pos)
                h, nl = jax.lax.scan(l_step, carry, (p["local"], c["local"]))
                h, ng = blocks.attn_mlp_decode(p["global"], cfg, h,
                                               c["global"], pos)
                return h, {"local": nl, "global": ng}
            x, nsuper = jax.lax.scan(super_step, x,
                                     (params["super"], cache["super"]))
            new_cache["super"] = nsuper
            if self.n_rem:
                def r_step(cc, plc):
                    pl_, cl = plc
                    return blocks.attn_mlp_decode(pl_, cfg, cc, cl, pos)
                x, nr = jax.lax.scan(r_step, x, (params["rem"], cache["rem"]))
                new_cache["rem"] = nr
        elif fam == "ssm":
            def step(carry, pc):
                p, c = pc
                return blocks.ssm_decode(p, cfg, carry, c, pos)
            x, ns = jax.lax.scan(step, x, (params["stack"], cache["stack"]))
            new_cache["stack"] = ns
        elif fam == "zamba":
            shared = params["shared"]

            def super_step(carry, pc):
                p, c = pc

                def s_step(cc, psc):
                    ps, cs = psc
                    return blocks.ssm_decode(ps, cfg, cc, cs, pos)
                h, nssm = jax.lax.scan(s_step, carry, (p["ssm"], c["ssm"]))
                h, nsh = blocks.attn_mlp_decode(shared, cfg, h, c["shared"],
                                                pos)
                return h, {"ssm": nssm, "shared": nsh}
            x, nsuper = jax.lax.scan(super_step, x,
                                     (params["super"], cache["super"]))
            new_cache["super"] = nsuper
            if self.n_rem:
                def r_step(cc, psc):
                    ps, cs = psc
                    return blocks.ssm_decode(ps, cfg, cc, cs, pos)
                x, nr = jax.lax.scan(r_step, x, (params["rem"], cache["rem"]))
                new_cache["rem"] = nr
        else:
            raise ValueError(fam)
        return self._head(params, x[:, -1]), new_cache

    # ------------------------------------------------------------------
    # fused multi-token decode
    # ------------------------------------------------------------------
    def decode_chunk(self, params: Params, cache: Cache, state: dict,
                     n_tokens: int, *, max_len: int,
                     greedy: bool = True) -> tuple[jax.Array, jax.Array,
                                                   dict, Cache]:
        """Fused decode of ``n_tokens`` steps: one ``lax.scan`` over the
        per-token ``decode_step`` body with sampling (argmax or
        PRNG-carried categorical), per-slot bookkeeping and stop
        conditions all inside the graph — one XLA dispatch and one host
        transfer per *chunk* instead of per token.

        ``state`` carries the per-slot decode state:
          tokens    (B,) int32  last sampled token per slot
          pos       (B,) int32  next cache write position per slot
          remaining (B,) int32  tokens still to emit per slot
          active    (B,) bool   slot is mid-generation
          key       PRNG key    sampling state (advanced when not greedy)

        A slot emits one token per step while active; it deactivates
        in-graph once ``remaining`` hits 0 or ``pos`` reaches
        ``max_len - 1`` (mid-chunk finishes), after which its state is
        frozen and further steps write only ignorable garbage into its
        (about-to-be-re-prefilled) cache row — the same contract the
        per-token engine path has for idle slots.

        Returns ``(tokens (B, n_tokens), emitted (B,), new_state,
        new_cache)``; per slot, only the first ``emitted`` tokens of its
        row are real. Jit this with ``donate_argnums`` on ``cache`` so
        the scan updates the KV rings in place (copy-free decode).
        """
        def step(carry, _):
            cache, tok, pos, rem, act, key = carry
            logits, cache = self.decode_step(params, tok[:, None], cache,
                                             pos)
            if greedy:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits).astype(jnp.int32)
            nxt = jnp.where(act, nxt, tok)
            pos = jnp.where(act, pos + 1, pos)
            rem = jnp.where(act, rem - 1, rem)
            nact = act & (rem > 0) & (pos < max_len - 1)
            return (cache, nxt, pos, rem, nact, key), (nxt, act)

        carry = (cache, state["tokens"], state["pos"], state["remaining"],
                 state["active"], state["key"])
        (cache, tok, pos, rem, act, key), (toks, emits) = jax.lax.scan(
            step, carry, None, length=n_tokens)
        new_state = {"tokens": tok, "pos": pos, "remaining": rem,
                     "active": act, "key": key}
        return (jnp.swapaxes(toks, 0, 1),
                jnp.sum(emits.astype(jnp.int32), axis=0), new_state, cache)
