"""Paged KV-cache layout: fixed-size blocks + per-sequence block tables.

The dense decode cache gives every sequence a private ``(max_len, ...)``
ring row, so a container's concurrency is hard-capped at ``n_slots`` no
matter how short the requests are. The paged layout (vLLM-style) breaks
the cache into ``block_size``-token physical pages shared by all
sequences; each sequence holds a row of page indices (the block table)
and only pays for the blocks its live prefix actually covers.

Per-layer group shapes (the model stacks layers on top, exactly like the
dense constructors in attention.py):

  attention:  ``{"table": (B, nblk) int32,
                 "k_pages"/"v_pages": (P+1, block_size, Hkv, hd)}``
              (+ ``k_scale_pages``/``v_scale_pages`` (P+1, bs, Hkv) f32
              for an int8 cache)
  MLA:        ``{"table": (B, nblk) int32,
                 "ckv_pages": (P+1, bs, kv_lora_rank),
                 "k_rope_pages": (P+1, bs, qk_rope_head_dim)}``

with ``nblk = max_len // block_size`` and ``P = max_blocks``. Page index
``P`` (the last page) is SCRATCH: unreserved table entries point at it,
so lockstep decode writes for idle/finished rows land there instead of
corrupting live sequences. Attention never reads garbage — validity is
``position < length`` and masked lanes contribute an exact 0.0 (see
kernels/ref.paged_decode_attention), which is what makes paged greedy
decode bit-identical to the dense baseline.

Only caches whose window covers the whole horizon page cleanly: a ring
with ``W < max_len`` wraps, and wrap-eviction has no block-table
equivalent. ``pageable(window, max_len)`` encodes that rule; the model
keeps short-window rings, SSM states and cross-attention memories dense
and pages everything else (see model.init_cache).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static description of a paged cache: ``max_blocks`` physical pages
    of ``block_size`` tokens, shared by every pageable layer group (one
    logical block allocation spans all layers)."""
    block_size: int = 16
    max_blocks: int = 64

    def __post_init__(self):
        if self.block_size < 1 or self.max_blocks < 1:
            raise ValueError("block_size and max_blocks must be >= 1")

    @property
    def scratch_page(self) -> int:
        """Index of the write-sink page for unreserved table entries."""
        return self.max_blocks

    def n_blocks(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` cache positions."""
        return -(-n_tokens // self.block_size)


def pageable(window: int, max_len: int) -> bool:
    """True when a cache window covers the whole horizon, i.e. the ring
    never wraps (slot == position) and the layer pages bit-exactly. A
    genuinely sliding window (W < max_len) stays on the dense ring."""
    return window == 0 or window >= max_len


def init_paged_attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype,
                          layout: PagedLayout) -> dict:
    """Paged counterpart of attention.init_attn_cache (full-window only)."""
    if max_len % layout.block_size:
        raise ValueError(f"max_len={max_len} must be a multiple of "
                         f"block_size={layout.block_size}")
    bs, P = layout.block_size, layout.max_blocks
    nblk = max_len // bs
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    table = jnp.full((batch, nblk), layout.scratch_page, jnp.int32)
    if cfg.kv_cache_dtype == "int8":
        return {
            "table": table,
            "k_pages": jnp.zeros((P + 1, bs, kv, hd), jnp.int8),
            "v_pages": jnp.zeros((P + 1, bs, kv, hd), jnp.int8),
            "k_scale_pages": jnp.zeros((P + 1, bs, kv), jnp.float32),
            "v_scale_pages": jnp.zeros((P + 1, bs, kv), jnp.float32),
        }
    return {
        "table": table,
        "k_pages": jnp.zeros((P + 1, bs, kv, hd), dtype),
        "v_pages": jnp.zeros((P + 1, bs, kv, hd), dtype),
    }


def init_paged_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype,
                         layout: PagedLayout) -> dict:
    """Paged counterpart of attention.init_mla_cache (latent + rope-key
    pages; the decode path gathers pages and reuses kops.mla_decode_ctx)."""
    if max_len % layout.block_size:
        raise ValueError(f"max_len={max_len} must be a multiple of "
                         f"block_size={layout.block_size}")
    bs, P = layout.block_size, layout.max_blocks
    nblk = max_len // bs
    return {
        "table": jnp.full((batch, nblk), layout.scratch_page, jnp.int32),
        "ckv_pages": jnp.zeros((P + 1, bs, cfg.kv_lora_rank), dtype),
        "k_rope_pages": jnp.zeros((P + 1, bs, cfg.qk_rope_head_dim), dtype),
    }


def is_paged_group(cache: dict) -> bool:
    """A per-layer cache dict produced by one of the paged constructors."""
    return "k_pages" in cache or "ckv_pages" in cache
