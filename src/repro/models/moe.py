"""Mixture-of-Experts: top-k router + capacity-bounded scatter dispatch.

Dispatch is position-in-expert scatter (cumsum over the one-hot expert
assignment), not the GShard dense one-hot einsum: the scatter adds zero
matmul FLOPs, so ``cost_analysis`` reflects only *useful* expert compute
(keeps the MODEL_FLOPS/HLO_FLOPs roofline ratio honest). Tokens beyond an
expert's capacity are dropped (standard capacity-factor semantics); the
router aux loss (Switch-style load balancing) is returned for training.

Under pjit the (E, C, d) buffers shard over the "model" axis — GSPMD emits
the all-to-all pair around the expert matmuls. A shard_map variant with
explicit collectives is a §Perf hillclimb, not the baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh, shard_map
from repro.configs.base import ArchConfig
from repro.models.layers import constrain, init_mlp, mlp_fwd, truncated_normal


def _mesh_info():
    """(data_axes, data_size, model_size) of the ambient mesh (if any)."""
    mesh = get_abstract_mesh()
    if not mesh.axis_names:
        return (), 1, 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dax = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    dsize = 1
    for a in dax:
        dsize *= sizes[a]
    return dax, dsize, sizes.get("model", 1)

def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = {
        "router": truncated_normal(ks[0], (d, cfg.n_experts), jnp.float32,
                                   d ** -0.5),
        # experts stacked on a leading E axis
        "experts": jax.vmap(
            lambda k: init_mlp(k, d, cfg.moe_d_ff, dtype))(
                jax.random.split(ks[1], cfg.n_experts)),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[2], d,
                               cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig, train: bool) -> int:
    cf = cfg.moe_train_cf if train else cfg.moe_eval_cf
    per = n_tokens * cfg.n_experts_per_tok / cfg.n_experts
    return max(4, min(n_tokens, int(per * cf + 0.5)))


def _dispatch_shard_map(experts: dict, cfg: ArchConfig, xt: jax.Array,
                        safe_e, safe_pos, keep, gate_vals,
                        G: int, Tg: int, C: int, act: str) -> jax.Array:
    """Expert dispatch + FFN + combine in ONE shard_map region (§Perf).

    GSPMD cannot prove that a dynamic scatter into an expert-sharded buffer
    is shard-local, so it materialises partial scatters and all-reduces the
    WHOLE (E, C, d) dispatch buffer every layer. This region states the
    locality explicitly:

      * scatter: each shard writes only the rows whose expert lives in its
        model shard (E | model: expert parallelism) or all rows of its own
        token group (E ∤ model: ff-parallel experts) — zero communication;
      * expert FFN: local matmuls against the shard's weight slice (the
        FSDP'd weights are all-gathered ONCE at region entry — the classic
        per-layer FSDP gather, ~weights/model_axis per chip);
      * combine: gather + gate + top-K sum LOCALLY, then one psum over
        "model" of the (Tg, d) per-token result — K·capacity_factor× less
        wire than reducing the expert outputs row-wise.
    """
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    d = xt.shape[-1]
    dax, dsize, msize = _mesh_info()
    # experts split over "model" when they divide it (expert parallelism);
    # otherwise every model shard handles all E experts and parallelism
    # comes from the ff-sharded expert weights (mixtral: E=8 < model=16)
    expert_parallel = msize > 1 and E % msize == 0
    Eloc = E // msize if expert_parallel else E
    dentry = dax if len(dax) > 1 else dax[0]
    dspec = P(dentry)

    tok_rep = jnp.repeat(xt.reshape(G, Tg, d), K, axis=1)      # (G, TgK, d)
    gates = gate_vals.reshape(G, Tg * K)

    def _erel(e):
        if not expert_parallel:
            return e, jnp.ones(e.shape, bool)
        j = jax.lax.axis_index("model")
        e_rel = e - j * Eloc
        return e_rel, (e_rel >= 0) & (e_rel < Eloc)

    def region(experts_l, e, pp, kp, g, t):
        e_rel, ok_e = _erel(e)
        se = jnp.where(ok_e, e_rel, Eloc)                      # Eloc = drop
        sp = jnp.where(ok_e, pp, 0)

        def scatter_one(eg, pg, tg):
            return jnp.zeros((Eloc, C, d), t.dtype).at[eg, pg].set(
                tg, mode="drop")

        buf = jax.vmap(scatter_one)(se, sp, t)                 # (Gl,Eloc,C,d)
        # local FFN: ff-split weights give a PARTIAL d output — the psum
        # below finishes the row-parallel reduction after the K-sum
        h = jax.vmap(lambda pe, xe: mlp_fwd(pe, xe, act))(
            experts_l, buf.swapaxes(0, 1)).swapaxes(0, 1)      # (Gl,Eloc,C,d)

        ok = ok_e & kp
        se2 = jnp.where(ok, e_rel, 0)
        sp2 = jnp.where(ok, pp, 0)

        def combine_one(hx, eg, pg, okg, gg):
            rows = hx[eg, pg]                                  # (TgK, d)
            rows = jnp.where(okg[:, None], rows, 0.0)
            rows = rows * gg[:, None].astype(rows.dtype)
            return jnp.sum(rows.reshape(Tg, K, d), axis=1)     # (Tg, d)

        part = jax.vmap(combine_one)(h, se2, sp2, ok, g)
        if msize > 1:
            part = jax.lax.psum(part, "model")
        return part                                            # (Gl, Tg, d)

    if expert_parallel:
        wspec = {k: P("model") for k in experts}
    else:  # ff dim sharded: (E, d, ff) for up/gate, (E, ff, d) for down
        wspec = {k: (P(None, "model") if k == "w_down"
                     else P(None, None, "model")) for k in experts}
    out = shard_map(
        region,
        in_specs=(wspec, dspec, dspec, dspec, dspec, dspec),
        out_specs=dspec)(experts, safe_e, safe_pos, keep, gates, tok_rep)
    return out.reshape(G * Tg, d)


def moe_fwd(p: dict, cfg: ArchConfig, x: jax.Array,
            act: str = "silu", train: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Dispatch is grouped when ``cfg.moe_dispatch_groups > 1``: tokens are
    partitioned into G groups (aligned with the data-parallel shards by the
    sharding constraint below), the position-in-expert cumsum and the
    (E, C, d) scatter run *within* each group, and capacity is per group —
    the standard per-device-capacity semantics of production MoE stacks.
    With G=1 this is one global dispatch.
    """
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalise

    # group count follows the ambient mesh (pod×data shards) so dispatch is
    # per-device on ANY mesh; the config knob covers the no-mesh case
    dax, dsize, msize = _mesh_info()
    G = max(1, cfg.moe_dispatch_groups)
    if dsize > 1 and T % dsize == 0:
        G = dsize
    while G > 1 and T % G:
        G //= 2
    Tg = T // G

    # ---- position-in-expert via per-group cumsum over (Tg*K) assignments
    flat_e = expert_idx.reshape(G, Tg * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (G, TgK, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot              # pos before self
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=2)[..., 0]

    C = _capacity(Tg, cfg, train)
    keep = pos < C                                             # (G, TgK)
    safe_e = jnp.where(keep, flat_e, E)                        # E => dropped
    safe_pos = jnp.where(keep, pos, 0)

    # ---- shard_map fast path: groups align with the data shards →
    # explicitly-local dispatch (expert- or ff-parallel FFN inside).
    # Token-starved steps (decode: ~8 tokens/group) skip it — there,
    # gathering the tiny token batch against statically-placed weights
    # (the 2D decode layout in launch/sharding.py) beats forcing token
    # locality and re-sharding the weights every step.
    if dsize > 1 and G == dsize and T >= 64 * dsize:
        out = _dispatch_shard_map(p["experts"], cfg, xt, safe_e, safe_pos,
                                  keep, gate_vals, G, Tg, C, act)
        if cfg.n_shared_experts:
            out = out + mlp_fwd(p["shared"], xt, act)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx, E,
                                     dtype=jnp.float32).sum(1), axis=0)
        return out.reshape(B, S, d), E * jnp.sum(me * ce)

    # ---- shard-local scatter into (G, E, C, d) buffers
    tok_rep = jnp.repeat(xt.reshape(G, Tg, d), K, axis=1)      # (G, TgK, d)

    def scatter_group(e, pp, t):
        return jnp.zeros((E, C, d), x.dtype).at[e, pp].set(t, mode="drop")

    buf = jax.vmap(scatter_group)(safe_e, safe_pos, tok_rep)   # (G, E, C, d)
    # groups ride the data axis, experts the model axis (dropped when E
    # doesn't divide — mixtral then runs tensor-parallel experts on ff).
    # NOTE §Perf iter 2 (refuted): forcing a two-step G-sharded→E-sharded
    # reshard here (hoping for one all-to-all) emitted all-to-all AND
    # collective-permute AND kept the all-reduce — 2.5× worse. GSPMD's own
    # propagation from this single constraint is the best layout found.
    buf = constrain(buf, ("pod", "data"), "model")

    # ---- batched expert FFN (xe: (G, C, d) per expert)
    h = jax.vmap(lambda pe, xe: mlp_fwd(pe, xe, act))(
        p["experts"], buf.swapaxes(0, 1))                      # (E, G, C, d)
    h = constrain(h, "model", ("pod", "data"))

    # ---- per-group gather back + gate-combine
    out_rep = jax.vmap(lambda hg, eg, pg: hg[eg % E, pg])(
        h.swapaxes(0, 1), safe_e, safe_pos)                    # (G, TgK, d)
    out_rep = constrain(out_rep, ("pod", "data"))
    out_rep = jnp.where(keep[..., None], out_rep, 0.0)
    out_rep = out_rep * gate_vals.reshape(G, Tg * K, 1).astype(x.dtype)
    out = jnp.sum(out_rep.reshape(T, K, d), axis=1)

    if cfg.n_shared_experts:
        out = out + mlp_fwd(p["shared"], xt, act)

    # ---- Switch-style load-balance aux loss (global)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx, E, dtype=jnp.float32).sum(1), axis=0)
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, d), aux


def moe_fwd_ref(p: dict, cfg: ArchConfig, x: jax.Array,
                act: str = "silu") -> jax.Array:
    """Dense (all-experts) oracle used by tests; no capacity drops."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.n_experts_per_tok)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    all_out = jax.vmap(lambda pe: mlp_fwd(pe, xt, act))(p["experts"])  # (E,T,d)
    mask = jax.nn.one_hot(expert_idx, cfg.n_experts)           # (T,K,E)
    combine = jnp.einsum("tke,tk->te", mask, gate_vals)
    out = jnp.einsum("etd,te->td", all_out, combine.astype(x.dtype))
    if cfg.n_shared_experts:
        out = out + mlp_fwd(p["shared"], xt, act)
    return out.reshape(B, S, d)
