"""Mamba2 block (arXiv:2405.21060): conv stem + SSD scan + gated norm.

Layout follows the reference Mamba2 block:
  in_proj -> [z | x | B | C | dt]; causal depthwise conv over [x|B|C];
  SSD over ``ssm_n_heads`` heads of width ``ssm_head_dim``; gated RMSNorm
  (norm(y * silu(z))); out_proj.

Both a full-sequence path (train / prefill, via the SSD chunk kernel) and a
single-token recurrent path (decode) are provided; they are numerically
consistent (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models.layers import constrain, rmsnorm_fwd, truncated_normal


def _dims(cfg: ArchConfig):
    di = cfg.d_inner
    nh = cfg.ssm_n_heads
    ng, ds = cfg.ssm_n_groups, cfg.ssm_state
    conv_dim = di + 2 * ng * ds
    return di, nh, ng, ds, conv_dim


def init_mamba2(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    di, nh, ng, ds, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * ng * ds + nh
    A = jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32,
                                   jnp.log(1.0), jnp.log(16.0)))
    return {
        "in_proj": truncated_normal(ks[0], (d, d_in_proj), dtype, d ** -0.5),
        "conv_w": truncated_normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                   dtype, cfg.ssm_conv_width ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.linspace(1e-3, 1e-1, nh), 1e-4))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out_proj": truncated_normal(ks[3], (di, d), dtype, di ** -0.5),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, nh, ng, ds, _ = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ng * ds], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv. xbc: (B, S, C); w: (K, C). Returns y and the
    trailing (K-1) inputs as the next conv state."""
    K = w.shape[0]
    pad = (jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
           if state is None else state.astype(xbc.dtype))
    xp = jnp.concatenate([pad, xbc], axis=1)
    y = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None] for i in range(K))
    y = y + b[None, None]
    new_state = xp[:, xp.shape[1] - (K - 1):]
    return jax.nn.silu(y), new_state


def mamba2_fwd(p: dict, cfg: ArchConfig, x: jax.Array,
               return_cache: bool = False):
    """x: (B, S, d) -> (B, S, d) [+ cache for subsequent decode]."""
    B, S, _ = x.shape
    di, nh, ng, ds, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, B_, C_ = jnp.split(xbc, [di, di + ng * ds], axis=-1)
    # SSD heads are the tensor-parallel dim (B/C groups replicated, ng=1);
    # out_proj is the matching row-parallel contraction
    xs = constrain(xs.reshape(B, S, nh, cfg.ssm_head_dim),
                   ("pod", "data"), None, "model")
    B_ = B_.reshape(B, S, ng, ds)
    C_ = C_.reshape(B, S, ng, ds)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
    dt = constrain(dt, ("pod", "data"), None, "model")
    A = -jnp.exp(p["A_log"])
    y, final_state = kops.ssd_scan(xs, dt.astype(xs.dtype), A, B_, C_,
                                   p["D"], chunk=min(cfg.ssm_chunk, S))
    y = y.reshape(B, S, di)
    y = rmsnorm_fwd(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_cache:
        return out, {"conv": conv_state, "state": final_state}
    return out


def mamba2_decode(p: dict, cfg: ArchConfig, x: jax.Array,
                  cache: dict) -> tuple[jax.Array, dict]:
    """x: (B, 1, d); cache: {conv: (B, K-1, conv_dim), state: (B,nh,hd,ds)}."""
    B = x.shape[0]
    di, nh, ng, ds, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   cache["conv"])
    xs, B_, C_ = jnp.split(xbc[:, 0], [di, di + ng * ds], axis=-1)
    xs = xs.reshape(B, nh, cfg.ssm_head_dim)
    B_ = B_.reshape(B, ng, ds)
    C_ = C_.reshape(B, ng, ds)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])
    from repro.kernels import ref as kref
    y, new_state = kref.ssd_decode_step(
        cache["state"], xs, dt.astype(xs.dtype), A, B_, C_, p["D"])
    y = y.reshape(B, 1, di)
    y = rmsnorm_fwd(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": conv_state, "state": new_state}


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    di, nh, ng, ds, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, cfg.ssm_head_dim, ds), dtype),
    }
