from repro.models.model import Model, family

__all__ = ["Model", "family"]
