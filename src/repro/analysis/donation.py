"""Donation auditor: prove every hot jit's donated operand really aliases.

The engine's macro-step is copy-free only because its jits donate the KV
cache (decode chunk, dense/paged prefill-row insertion, paged table
writes and scrubs, CoW page copies). jax treats an unusable donation as
a *warning* and silently copies — a one-line model change (returning a
reshaped tree, a dtype change on one leaf) reintroduces a full-cache
copy per step with no test failing. This auditor lowers each hot jit for
every model family × cache mode from ``ShapeDtypeStruct``s (no params
materialised, nothing executed) and fails unless the donated tree's
every array leaf carries an aliasing marker in the lowered module
(``core/hlo_analysis.parse_donation``).

The deliberately-undonated executables (``paged_gather`` — a pure read
the suffix path must not consume) are audited for the OPPOSITE
property: zero aliasing markers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.analysis.report import Finding
from repro.core.hlo_analysis import parse_donation

# one representative per model family (models/model.py's family table) —
# the same six the paged parity suite pins down
FAMILY_ARCHS = (
    "qwen3-0.6b",        # dense
    "gemma3-27b",        # gemma (local/global sliding-window pattern)
    "mixtral-8x22b",     # moe
    "mamba2-2.7b",       # ssm
    "zamba2-7b",         # zamba (ssm + shared attention)
    "whisper-large-v3",  # whisper (encoder-decoder)
)

_MAX_LEN = 64
_BLOCK = 16
_N_SLOTS = 2
_CHUNK = 8


def _struct(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)


def _n_leaves(tree) -> int:
    return len(jax.tree.leaves(tree))


@functools.lru_cache(maxsize=None)
def _engine_for(arch: str, mode: str):
    """A ServingEngine over param STRUCTS — engine construction only
    touches params to store them, so the jit builders work unexecuted."""
    from repro.configs.registry import get_config
    from repro.models.model import Model
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = get_config(arch + "-reduced")
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return ServingEngine(model, params, EngineConfig(
        n_slots=_N_SLOTS, max_len=_MAX_LEN, cache=mode, block_size=_BLOCK))


def _check(label: str, lowered, donated_tree, *, expect_none=False,
           what="cache") -> list[Finding]:
    info = parse_donation(lowered.as_text())
    if expect_none:
        if info.n_aliased:
            return [Finding(
                "donation", "DON002", label,
                f"pure-read executable aliases {info.n_aliased} "
                "operand(s) — a donation crept into a path that must "
                "leave its input tree alive")]
        return []
    want = _n_leaves(donated_tree)
    if info.n_aliased < want:
        return [Finding(
            "donation", "DON001", label,
            f"donated {what} has {want} array leaves but only "
            f"{info.n_aliased} alias an output "
            f"({len(info.aliased_outputs)} aliased, "
            f"{info.buffer_donors} deferred donors) — XLA will silently "
            "copy the rest every dispatch")]
    return []


def _chunk_state_struct(eng):
    n_rows = len(eng.slots)
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    return {"tokens": i32((n_rows,)), "pos": i32((n_rows,)),
            "remaining": i32((n_rows,)),
            "active": jax.ShapeDtypeStruct((n_rows,), jnp.bool_),
            "key": _struct(jax.random.PRNGKey(0))}


def _prefill_batch_struct(eng, n: int, bl: int):
    cfg = eng.model.cfg
    batch = {"tokens": jax.ShapeDtypeStruct((n, bl), jnp.int32)}
    if cfg.n_encoder_layers:
        batch["audio_frames"] = jax.ShapeDtypeStruct(
            (n, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (n, cfg.n_vision_tokens, cfg.vision_embed_dim), jnp.float32)
    return batch


def audit_engine(arch: str, mode: str) -> list[Finding]:
    """Lower every hot jit of one (family, cache-mode) engine and verify
    its donation contract."""
    findings: list[Finding] = []
    eng = _engine_for(arch, mode)
    params = eng.params                      # already structs
    cache_s = _struct(eng.cache)
    site = f"{arch}/{mode}"

    # -- fused decode chunk: donates the cache (arg 1)
    low = eng._chunk_fn(_CHUNK).lower(params, cache_s,
                                      _chunk_state_struct(eng))
    findings += _check(f"{site}/chunk", low, cache_s)

    # -- prefill: pure (fresh mini-cache built inside) — nothing donated
    batch = _prefill_batch_struct(eng, 1, _BLOCK)
    idx = jax.ShapeDtypeStruct((1,), jnp.int32)
    low = eng._prefill_fn(1, _BLOCK).lower(params, batch, idx)
    findings += _check(f"{site}/prefill", low, None, expect_none=True)

    cb = eng.cache_backend
    src_s = jax.eval_shape(
        lambda: eng.model.init_cache(1, _BLOCK if mode == "paged"
                                     else _MAX_LEN))
    if mode == "dense":
        low = cb._insert_fn().lower(cache_s, src_s,
                                    jax.ShapeDtypeStruct((1,), jnp.int32))
        findings += _check(f"{site}/insert", low, cache_s)
        return findings

    # -- paged: prefill-row scatter, table write, scrub, CoW page copy
    nblk = _MAX_LEN // _BLOCK
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    low = cb._insert_fn().lower(cache_s, src_s, i32((1,)),
                                i32((1, nblk)), i32(()))
    findings += _check(f"{site}/insert", low, cache_s)

    low = cb._append_fn().lower(cache_s, i32(()), i32((1,)), i32((1,)))
    findings += _check(f"{site}/append", low, cache_s)

    low = cb._clear_fn().lower(cache_s, i32((1,)))
    findings += _check(f"{site}/clear", low, cache_s)

    low = cb._copy_fn().lower(cache_s, i32(()), i32(()))
    findings += _check(f"{site}/copy", low, cache_s)

    low = cb._gather_fn().lower(cache_s, i32((1, nblk)),
                                i32((_BLOCK,)))
    findings += _check(f"{site}/gather", low, None, expect_none=True)

    # -- residual-suffix prefill: pure, like full prefill (the families
    # the sharing gate admits — see ServingEngine._share)
    if eng.model.fam in ("dense", "moe"):
        batch = _prefill_batch_struct(eng, 1, _BLOCK)
        ctx = jax.eval_shape(lambda t: cb._gather_fn()(
            t, jnp.zeros((1, nblk), jnp.int32),
            jnp.arange(_BLOCK)), cache_s)
        low = eng._suffix_prefill_fn(1, _BLOCK, _BLOCK).lower(
            params, batch, ctx, jax.ShapeDtypeStruct((1,), jnp.int32))
        findings += _check(f"{site}/prefill_sfx", low, None,
                           expect_none=True)
    return findings


def run(archs=FAMILY_ARCHS, modes=("dense", "paged")) -> list[Finding]:
    findings: list[Finding] = []
    for arch in archs:
        for mode in modes:
            findings += audit_engine(arch, mode)
    return findings
