"""Concurrency lint: class attributes written from two threads, caught.

The serving stack runs three kinds of threads beside the router's main
loop (serving/backend.py): the ``poll()`` step executor (one macro-step
per active container per poll), the ``drain()`` wave workers (one
``engine.run()`` per container), and the process child's heartbeat.
The safety argument is structural — ``poll`` joins every future before
touching shared state, ``drain`` joins its workers, the heartbeat only
writes through a pipe under a lock — and nothing enforces it: moving a
``self._alive[cid] = False`` into a worker callback would be a silent
data race that no test reliably catches.

This linter rebuilds that argument from the AST, per class:

* **thread roots** — targets of ``threading.Thread(target=...)`` and
  ``<executor>.submit(...)`` that name ``self.<method>`` or a function
  nested in the spawning method. Each non-joined root is its own
  execution context; roots whose spawning method also calls ``.join()``
  / ``.result()`` are *fork-join scoped* but still concurrent with
  their sibling workers.
* **context propagation** — ``self.X()`` edges carry a root's context
  into helper methods; methods never reached from a root run in the
  single ``main`` context (the backend contract: one router thread
  drives the public API).
* **write sites** — ``self.attr = ...``, ``self.attr += ...`` and
  ``self.attr[i] = ...`` (method calls like ``deque.append`` are
  GIL-atomic and deliberately out of scope), with the enclosing
  ``with self.<...lock...>:`` blocks recorded as the site's lock set.

Findings:

* ``CON001`` — an attribute written from ≥2 distinct contexts with no
  common lock.
* ``CON002`` — a read-modify-write (``+=`` or ``self.a[i] += ...``)
  inside a root spawned in a loop/comprehension (parallel siblings
  race each other even though ``main`` is parked at the join) without
  a lock.

Suppress a deliberate site with ``# analysis: allow(concurrency)``.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib

from repro.analysis.report import Finding, line_suppressed

_REPRO = pathlib.Path(__file__).resolve().parents[1]

DEFAULT_TARGETS = ("serving/backend.py", "serving/router.py",
                   "serving/process_pool.py", "serving/engine.py",
                   "workload/replay.py")

MAIN = "main"


def _self_attr(node: ast.AST) -> str | None:
    """``self.X`` -> ``"X"``; anything else -> None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _write_target_attr(target: ast.AST) -> str | None:
    """The self-attribute a write target mutates: ``self.a``,
    ``self.a[i]`` and ``self.a.b`` all mutate object state reachable
    through ``self.a``."""
    node = target
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        attr = _self_attr(node)
        if attr is not None:
            return attr
        node = node.value
    return None


@dataclasses.dataclass
class _Write:
    attr: str
    lineno: int
    locks: frozenset[str]
    aug: bool                      # read-modify-write


@dataclasses.dataclass
class _Root:
    func: str                      # method or nested-function name
    spawner: str                   # method that spawned it
    lineno: int
    joined: bool                   # spawner also joins/results
    fanout: bool                   # spawned inside a loop/comprehension


class _MethodScan(ast.NodeVisitor):
    """One method body: write sites (with lock sets), self-call edges,
    thread-root spawns, and nested function definitions."""

    def __init__(self, lines: list[str]):
        self.lines = lines
        self.writes: list[_Write] = []
        self.calls: set[str] = set()
        self.spawn_targets: list[tuple[str, int, bool]] = []  # fanout flag
        self.nested: dict[str, ast.FunctionDef] = {}
        self.joins = False
        self._locks: list[str] = []
        self._loop_depth = 0

    # -- lock tracking --------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        held = [a for item in node.items
                if (a := _self_attr(item.context_expr)) is not None
                and "lock" in a.lower()]
        self._locks.extend(held)
        self.generic_visit(node)
        for _ in held:
            self._locks.pop()

    # -- write sites ----------------------------------------------------
    def _record(self, target: ast.AST, lineno: int, aug: bool) -> None:
        attr = _write_target_attr(target)
        if attr is None:
            return
        if line_suppressed(self.lines, lineno, "concurrency"):
            return
        self.writes.append(_Write(attr, lineno,
                                  frozenset(self._locks), aug))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record(t, node.lineno, aug=False)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record(node.target, node.lineno, aug=True)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record(node.target, node.lineno, aug=False)
        self.generic_visit(node)

    # -- calls, spawns, joins -------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr in ("join", "result"):
                self.joins = True
            target = None
            if f.attr == "Thread":                      # threading.Thread
                for kw in node.keywords:
                    if kw.arg == "target":
                        target = kw.value
            elif f.attr == "submit" and node.args:      # executor.submit
                # backend.submit(cid, req) takes an int first — executor
                # submits take a callable; only attribute/name callables
                # that are not plain data args are roots
                cand = node.args[0]
                if isinstance(cand, (ast.Attribute, ast.Name,
                                     ast.Lambda)):
                    target = cand
            if target is not None:
                name = None
                if (a := _self_attr(target)) is not None:
                    name = a
                elif isinstance(target, ast.Name):
                    name = target.id
                if name is not None:
                    self.spawn_targets.append(
                        (name, node.lineno, self._loop_depth > 0))
            if isinstance(f.value, ast.Name) and f.value.id == "self":
                self.calls.add(f.attr)
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_While(self, node: ast.While) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_ListComp(self, node: ast.ListComp) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_SetComp = visit_ListComp
    visit_GeneratorExp = visit_ListComp

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested def: scanned separately as a potential thread root
        self.nested[node.name] = node
        # do NOT recurse — its body is not part of this method's context


def _scan_body(fn: ast.FunctionDef, lines: list[str]) -> _MethodScan:
    scan = _MethodScan(lines)
    for stmt in fn.body:
        scan.visit(stmt)
    return scan


class _ClassAudit:
    def __init__(self, cls: ast.ClassDef, path: pathlib.Path,
                 lines: list[str]):
        self.name = cls.name
        self.path = path
        self.methods = {n.name: n for n in cls.body
                        if isinstance(n, ast.FunctionDef)}
        self.scans = {name: _scan_body(fn, lines)
                      for name, fn in self.methods.items()}
        # nested thread-root functions get their own scans
        self.roots: list[_Root] = []
        for meth, scan in list(self.scans.items()):
            for target, lineno, fanout in scan.spawn_targets:
                fn = scan.nested.get(target) or self.methods.get(target)
                if fn is None:
                    continue            # cross-object (eng.step): the
                                        # fork-join in poll() is the
                                        # engine's safety story
                if target in scan.nested and target not in self.scans:
                    self.scans[target] = _scan_body(fn, lines)
                self.roots.append(_Root(target, meth, lineno,
                                        scan.joins, fanout))

    def contexts(self) -> dict[str, set[str]]:
        """method/function name -> set of execution contexts. A root's
        context flows through ``self.X()`` edges; everything else is
        ``main``. ``__init__`` is construction-time and excluded."""
        ctx: dict[str, set[str]] = {
            name: set() for name in self.scans if name != "__init__"}
        for root in self.roots:
            label = f"thread:{root.func}"
            work = [root.func]
            while work:
                m = work.pop()
                if m not in ctx or label in ctx[m]:
                    continue
                ctx[m].add(label)
                work.extend(self.scans[m].calls)
        for name, c in ctx.items():
            is_pure_root = any(r.func == name for r in self.roots)
            if not c or not is_pure_root:
                c.add(MAIN)
        return ctx

    def audit(self) -> list[Finding]:
        findings: list[Finding] = []
        ctx = self.contexts()
        # attr -> list of (context, write)
        sites: dict[str, list[tuple[str, _Write, str]]] = {}
        for meth, contexts in ctx.items():
            for w in self.scans[meth].writes:
                for c in contexts:
                    sites.setdefault(w.attr, []).append((c, w, meth))
        for attr, entries in sites.items():
            by_ctx = {c for c, _, _ in entries}
            if len(by_ctx) > 1:
                common = frozenset.intersection(
                    *[w.locks for _, w, _ in entries])
                if not common:
                    locs = sorted({w.lineno for _, w, _ in entries})
                    findings.append(Finding(
                        "concurrency", "CON001",
                        f"{self.path.name}:{locs[0]}",
                        f"{self.name}.{attr} is written from contexts "
                        f"{sorted(by_ctx)} (lines {locs}) with no "
                        "common lock — serialise the writes or move "
                        "them into one context"))
        # sibling races inside fan-out roots
        for root in self.roots:
            if not root.fanout:
                continue
            label = f"thread:{root.func}"
            for meth, contexts in ctx.items():
                if label not in contexts:
                    continue
                for w in self.scans[meth].writes:
                    if w.aug and not w.locks:
                        findings.append(Finding(
                            "concurrency", "CON002",
                            f"{self.path.name}:{w.lineno}",
                            f"{self.name}.{w.attr} read-modify-write "
                            f"inside fan-out thread root "
                            f"{root.func}() — parallel workers race "
                            "each other; guard with a lock"))
        return findings


def run(paths: tuple[pathlib.Path, ...] | None = None) -> list[Finding]:
    if paths is None:
        paths = tuple(_REPRO / n for n in DEFAULT_TARGETS)
    findings: list[Finding] = []
    for path in paths:
        src = path.read_text()
        tree = ast.parse(src)
        lines = src.splitlines()
        for node in tree.body:
            if isinstance(node, ast.ClassDef):
                findings += _ClassAudit(node, path, lines).audit()
    return findings
