"""Compile-key enumerator: the jit-cache key set, derived statically.

A serving engine must compile a BOUNDED set of executables — an
unbounded key (a raw prompt length in a prefill key, a raw clamp value
in a chunk key) turns ragged traffic into a compile spike mid-serving.
This analyzer derives the key space of the shared jit cache
(``engine._JIT_CACHE``) for a reference config and fails if it exceeds
the budget, and cross-checks the SOURCE for drift:

* every ``self._jits[...]`` key kind appearing in engine.py / cache.py
  must be one the enumerator models (a new kind is an unmodelled — and
  potentially unbounded — compile axis until it is registered here);
* ``_bucket`` must keep rounding to a power of two past the table (its
  image over [1, 64k] is checked, not assumed);
* the chunk-length clamp in ``_decode_chunk`` must keep its
  power-of-two rounding shift (``1 << (exact.bit_length() - 1)``) — the
  AST is checked for the shift so a well-meaning "use the exact clamp"
  edit is caught before it ships log2→linear compile growth.
"""
from __future__ import annotations

import ast
import math
import pathlib

from repro.analysis.report import Finding

_SERVING = pathlib.Path(__file__).resolve().parents[1] / "serving"

# key kinds the enumerator models — keep in sync with count_keys()
KNOWN_KINDS = {
    "decode", "prefill", "prefill_sfx", "chunk",
    "insert", "paged_clear", "paged_copy", "paged_append", "paged_gather",
}

# compiled-executable budget for the reference config below; generous
# headroom over the current count (see count_keys) but far below what a
# single unbounded axis would produce
DEFAULT_BUDGET = 4096


def _buckets_upto(max_len: int) -> int:
    """Distinct prefill widths ``_bucket`` can emit for prompts up to
    ``max_len`` — table entries plus power-of-two extensions."""
    from repro.serving.engine import PROMPT_BUCKETS, _bucket
    return len({_bucket(n) for n in range(1, max_len + 1)}) \
        if max_len >= 1 else 0


def count_keys(n_slots: int = 4, max_len: int = 512,
               block_size: int = 16, chunk_tokens: int = 32) -> dict:
    """Upper bound on jit-cache keys per kind for one engine config
    serving prompts up to ``max_len``. Every axis is a bounded function
    of the config — that is the property the budget check pins."""
    n_widths = _buckets_upto(max_len)
    n_offsets = max_len // block_size           # suffix rope offsets
    n_chunk = int(math.log2(chunk_tokens)) + 1  # power-of-two lengths
    return {
        "decode": 1,
        "prefill": n_slots * n_widths,
        "prefill_sfx": n_slots * n_widths * n_offsets,
        "chunk": 2 * n_chunk,                   # dense + paged
        "insert": 2,
        "paged_clear": 1,
        "paged_copy": 1,
        "paged_append": 1,
        "paged_gather": 1,
    }


def _jit_key_kinds(path: pathlib.Path) -> list[tuple[str, int]]:
    """(kind, lineno) for every jit-cache key literal in ``path``: tuple
    literals assigned to ``key`` in a method that indexes ``self._jits``,
    plus direct ``self._jits[("kind", ...)]`` subscripts, plus the
    string-literal kinds (``"decode"``)."""
    tree = ast.parse(path.read_text())
    out: list[tuple[str, int]] = []

    def is_jits_sub(node: ast.AST) -> bool:
        return (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Attribute)
                and node.value.attr == "_jits")

    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        uses_jits = any(is_jits_sub(n) for n in ast.walk(fn))
        if not uses_jits:
            continue
        for node in ast.walk(fn):
            lit = None
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "key"
                            for t in node.targets)):
                lit = node.value
            elif is_jits_sub(node):
                lit = node.slice
            if isinstance(lit, ast.Tuple) and lit.elts:
                head = lit.elts[0]
                if isinstance(head, ast.Constant) and isinstance(
                        head.value, str):
                    out.append((head.value, head.lineno))
            elif isinstance(lit, ast.Constant) and isinstance(
                    lit.value, str):
                out.append((lit.value, lit.lineno))
    return out


def _chunk_shift_present(path: pathlib.Path) -> bool:
    """Does ``_decode_chunk`` still derive ``n_tokens`` via a left
    shift (the power-of-two rounding)?"""
    tree = ast.parse(path.read_text())
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef) and fn.name == "_decode_chunk":
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign)
                        and any(isinstance(t, ast.Name)
                                and t.id == "n_tokens"
                                for t in node.targets)):
                    return any(isinstance(s, ast.BinOp)
                               and isinstance(s.op, ast.LShift)
                               for s in ast.walk(node.value))
    return False


def run(engine_path: pathlib.Path | None = None,
        cache_path: pathlib.Path | None = None,
        budget: int = DEFAULT_BUDGET) -> list[Finding]:
    engine_path = engine_path or _SERVING / "engine.py"
    cache_path = cache_path or _SERVING / "cache.py"
    findings: list[Finding] = []

    # -- drift: unmodelled key kinds
    for path in (engine_path, cache_path):
        for kind, lineno in _jit_key_kinds(path):
            if kind not in KNOWN_KINDS:
                findings.append(Finding(
                    "compile-keys", "KEY001", f"{path.name}:{lineno}",
                    f"jit-cache key kind {kind!r} is not modelled by the "
                    "compile-key enumerator — register it in "
                    "repro.analysis.compile_keys.KNOWN_KINDS and "
                    "count_keys() so its boundedness is checked"))

    # -- bucket image must stay power-of-two past the table
    from repro.serving.engine import PROMPT_BUCKETS, _bucket
    for n in range(1, 1 << 16):
        b = _bucket(n)
        if b < n or (b not in PROMPT_BUCKETS and b & (b - 1)):
            findings.append(Finding(
                "compile-keys", "KEY002", f"_bucket({n})={b}",
                "prompt bucketing no longer rounds to a bounded set — "
                "prefill keys become unbounded in prompt length"))
            break

    # -- chunk clamp must keep its power-of-two rounding
    if not _chunk_shift_present(engine_path):
        findings.append(Finding(
            "compile-keys", "KEY003", f"{engine_path.name}:_decode_chunk",
            "n_tokens is no longer rounded down to a power of two — "
            "each distinct ragged clamp value would compile its own "
            "decode-chunk executable"))

    # -- budget
    counts = count_keys()
    total = sum(counts.values())
    if total > budget:
        findings.append(Finding(
            "compile-keys", "KEY004", "count_keys()",
            f"reference-config jit key bound {total} exceeds the "
            f"budget {budget} ({counts})"))
    return findings
