"""Wire auditor: the process-pipe payloads and the pre-affinity closure.

Two invariants keep the process backend honest:

1. **Import-light pre-affinity closure.** ``spawn_pinned`` promises the
   child applies its cpuset before jax initialises — but spawn pickles
   the child target *by reference*, and unpickling it at bootstrap
   imports its module (and every module-scope import underneath,
   package ``__init__``s included) BEFORE ``sched_setaffinity`` runs.
   Every module a spawn payload can reference pre-affinity —
   ``serving/child.py`` (the child body), ``core/testbed.py`` (the
   pinned entry point), the wire dataclasses (``events.py``,
   ``faults.py``), and ``configs/base.py`` (the model config crossing
   the pipe) — must therefore not reach a module-scope ``import jax``
   transitively. This auditor walks that closure statically through the
   AST (following ``repro.*`` imports only; conditional/function-local
   imports don't run at import time and are skipped).

2. **Picklable, primitive payloads.** Everything crossing a process
   pipe (the event/fault dataclasses, the ``_engine_config_wire`` dict)
   must pickle round-trip and must not smuggle device arrays or
   module-bound callables: every dataclass in events.py / faults.py is
   instantiated with dummy field values and round-tripped, and the wire
   dict of a default ``EngineConfig`` is checked to contain primitives
   only.

Findings: ``WIR001`` module-scope jax import in the pre-affinity
closure; ``WIR002`` unpicklable wire dataclass; ``WIR003`` non-primitive
value in the engine-config wire dict.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import pickle
import typing

from repro.analysis.report import Finding

_SRC = pathlib.Path(__file__).resolve().parents[2]   # .../src

# modules a spawn payload references before the cpuset exists
PRE_AFFINITY_MODULES = (
    "repro.serving.child",
    "repro.core.testbed",
    "repro.serving.events",
    "repro.serving.faults",
    "repro.configs.base",
)

HEAVY = ("jax", "jaxlib")

WIRE_DATACLASS_MODULES = ("repro.serving.events", "repro.serving.faults",
                          "repro.workload.traces", "repro.workload.slo",
                          "repro.workload.replay")


def _module_path(modname: str) -> pathlib.Path | None:
    rel = pathlib.Path(*modname.split("."))
    for cand in (_SRC / rel / "__init__.py", _SRC / rel.with_suffix(".py")):
        if cand.is_file():
            return cand
    return None


def _module_scope_imports(path: pathlib.Path) -> list[tuple[str, int]]:
    """(imported module, lineno) for every import executed AT IMPORT
    TIME — module scope plus class bodies; function bodies are deferred
    and skipped."""
    tree = ast.parse(path.read_text())
    out: list[tuple[str, int]] = []
    work: list[ast.AST] = list(tree.body)
    while work:
        node = work.pop()
        if isinstance(node, ast.Import):
            out.extend((a.name, node.lineno) for a in node.names)
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.level == 0:
                out.append((node.module, node.lineno))
                # ``from pkg import name`` imports pkg.name when name is
                # a submodule; emit the candidate and let the closure
                # walk drop it if no such module file exists
                out.extend((f"{node.module}.{a.name}", node.lineno)
                           for a in node.names)
        elif isinstance(node, (ast.If, ast.Try, ast.ClassDef, ast.With)):
            # function/lambda bodies are deferred and deliberately NOT
            # descended into; these compound statements run at import
            work.extend(ast.iter_child_nodes(node))
    return out


def _closure_findings(root: str) -> list[Finding]:
    """Walk ``root``'s import-time closure (repro.* edges and their
    package ``__init__``s) and flag any module-scope jax import."""
    findings: list[Finding] = []
    seen: set[str] = set()
    work = [root]
    while work:
        mod = work.pop()
        if mod in seen:
            continue
        seen.add(mod)
        # importing a.b.c first imports packages a and a.b
        parts = mod.split(".")
        for i in range(1, len(parts)):
            work.append(".".join(parts[:i]))
        path = _module_path(mod)
        if path is None:
            continue                      # namespace package / stdlib
        for imported, lineno in _module_scope_imports(path):
            top = imported.split(".")[0]
            if top in HEAVY:
                findings.append(Finding(
                    "wire", "WIR001",
                    f"{path.relative_to(_SRC)}:{lineno}",
                    f"module-scope import of {imported!r} is reachable "
                    f"from pre-affinity module {root!r} (via {mod}) — "
                    "the process child would initialise jax before its "
                    "cpuset is applied; defer the import into the "
                    "function that needs it"))
            elif top == "repro":
                work.append(imported)
    return findings


def _dummy_for(tp) -> object:
    origin = typing.get_origin(tp)
    if origin is typing.Union:
        args = [a for a in typing.get_args(tp) if a is not type(None)]
        return _dummy_for(args[0]) if args else None
    if dataclasses.is_dataclass(tp):
        return _dummy_instance(tp)
    table = {int: 0, float: 0.0, str: "x", bool: False, bytes: b"",
             tuple: (), list: [], dict: {}, typing.Any: None}
    return table.get(tp, None)


def _dummy_instance(cls):
    hints = typing.get_type_hints(cls)
    kw = {f.name: (f.default if f.default is not dataclasses.MISSING
                   else _dummy_for(hints.get(f.name)))
          for f in dataclasses.fields(cls)}
    # validated enum-ish str fields (Fault.kind checks against _KINDS):
    # a constructor rejection is not a pickling failure — use a legal
    # value when the class advertises one
    kinds = getattr(cls, "_KINDS", None)
    if kinds and "kind" in kw and kw["kind"] not in kinds:
        kw["kind"] = kinds[0]
    return cls(**kw)


def _pickle_findings() -> list[Finding]:
    import importlib
    findings: list[Finding] = []
    for modname in WIRE_DATACLASS_MODULES:
        mod = importlib.import_module(modname)
        for name in dir(mod):
            cls = getattr(mod, name)
            if not (isinstance(cls, type) and dataclasses.is_dataclass(cls)
                    and cls.__module__ == modname):
                continue
            try:
                inst = _dummy_instance(cls)
                back = pickle.loads(pickle.dumps(inst))
                if back != inst:
                    raise ValueError("round-trip changed the value")
            except Exception as e:
                findings.append(Finding(
                    "wire", "WIR002", f"{modname}.{name}",
                    f"wire dataclass does not pickle round-trip: {e}"))
    return findings


_PRIMITIVE = (int, float, str, bool, bytes, type(None))


def _wire_dict_findings() -> list[Finding]:
    from repro.serving.backend import _engine_config_wire
    from repro.serving.engine import EngineConfig
    findings: list[Finding] = []
    for key, val in _engine_config_wire(EngineConfig()).items():
        ok = isinstance(val, _PRIMITIVE) or (
            isinstance(val, tuple)
            and all(isinstance(v, _PRIMITIVE) for v in val))
        if not ok:
            findings.append(Finding(
                "wire", "WIR003", f"_engine_config_wire()[{key!r}]",
                f"engine-config wire value is {type(val).__name__}, not "
                "a picklable primitive — the child would unpickle a "
                "module-bound object (hence import it) pre-affinity"))
    return findings


def run(roots: tuple[str, ...] = PRE_AFFINITY_MODULES) -> list[Finding]:
    findings: list[Finding] = []
    for root in roots:
        findings += _closure_findings(root)
    findings += _pickle_findings()
    findings += _wire_dict_findings()
    return findings
