"""Pallas kernel checkers: spec-level invariants, no kernel execution.

Each TPU kernel in ``src/repro/kernels`` is invoked under
``jax.eval_shape`` with ``pl.pallas_call`` monkeypatched to CAPTURE the
grid / BlockSpecs / scratch shapes / operand avals instead of building
the kernel — nothing compiles, nothing runs, and the real jit wrappers
are bypassed (``fn.__wrapped__``) so no fake executable can pollute the
shared jit cache. The captured spec is then checked:

* block-shape divisibility — every BlockSpec dim must divide its
  operand dim (our kernels tile exactly; a non-dividing block means
  silent padding or a runtime error on the accelerator);
* index-map bounds — each index map is evaluated at every grid corner
  with worst-case scalar-prefetch values (block tables filled with the
  LAST physical page) and must keep ``(idx+1)·block ≤ shape``;
* VMEM budget — double-buffered block tiles plus scratch must fit the
  per-core VMEM (~16 MiB on current TPUs; the guide's figure);
* dtype consistency — scratch accumulators must be f32, and int8 page
  operands must travel with f32 scale operands (the dequant contract).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import itertools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.analysis.report import Finding

VMEM_BYTES = 16 * 1024 * 1024       # per-core VMEM (pallas guide)


@dataclasses.dataclass
class KernelSpec:
    """One captured ``pl.pallas_call`` invocation."""
    name: str
    grid: tuple
    in_specs: list                   # BlockSpec per (non-prefetch) operand
    out_specs: list
    scratch_shapes: list
    num_scalar_prefetch: int
    prefetch_args: list              # avals of the scalar-prefetch operands
    operands: list                   # avals of the blocked operands
    out_shapes: list                 # ShapeDtypeStructs


def _as_list(x) -> list:
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


@contextlib.contextmanager
def capture_pallas(sink: list, name: str):
    """Patch ``pl.pallas_call`` to record specs and return zeros of
    ``out_shape`` — valid under ``jax.eval_shape`` tracing."""
    real = pl.pallas_call

    def fake(kernel, out_shape=None, *, grid_spec=None, grid=None,
             in_specs=None, out_specs=None, scratch_shapes=None,
             **kw):
        if grid_spec is not None:
            grid = grid_spec.grid
            in_specs = grid_spec.in_specs
            out_specs = grid_spec.out_specs
            scratch_shapes = grid_spec.scratch_shapes
            npf = getattr(grid_spec, "num_scalar_prefetch", 0)
        else:
            npf = 0
        spec = KernelSpec(
            name=name, grid=tuple(grid) if grid else (),
            in_specs=_as_list(in_specs), out_specs=_as_list(out_specs),
            scratch_shapes=_as_list(scratch_shapes),
            num_scalar_prefetch=npf, prefetch_args=[], operands=[],
            out_shapes=jax.tree.leaves(
                out_shape, is_leaf=lambda x: hasattr(x, "shape")))

        def runner(*args):
            avals = [jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a))
                     for a in args]
            spec.prefetch_args = avals[:npf]
            spec.operands = avals[npf:]
            sink.append(spec)
            outs = [jnp.zeros(s.shape, s.dtype) for s in spec.out_shapes]
            if isinstance(out_shape, (list, tuple)):
                return outs
            return outs[0]
        return runner

    pl.pallas_call = fake
    try:
        yield
    finally:
        pl.pallas_call = real


def _grid_corners(grid: tuple):
    axes = [(0,) if g <= 1 else (0, g - 1) for g in grid]
    return itertools.product(*axes)


def _worst_case_prefetch(spec: KernelSpec, table_fill: dict[int, int]):
    """Concrete numpy stand-ins for the scalar-prefetch operands, filled
    with the caller-declared worst-case value (e.g. the highest physical
    page index a block table may hold)."""
    out = []
    for i, aval in enumerate(spec.prefetch_args):
        fill = table_fill.get(i, 0)
        out.append(np.full(aval.shape, fill,
                           dtype=aval.dtype if np.issubdtype(
                               np.dtype(aval.dtype), np.integer)
                           else np.int32))
    return out


def check_spec(spec: KernelSpec,
               table_fill: dict[int, int] | None = None,
               int8_scales_expected: bool = False) -> list[Finding]:
    findings: list[Finding] = []
    table_fill = table_fill or {}
    site = f"kernels/{spec.name}"

    pairs = (list(zip(spec.in_specs, spec.operands, itertools.repeat("in")))
             + list(zip(spec.out_specs, spec.out_shapes,
                        itertools.repeat("out"))))
    if len(spec.in_specs) != len(spec.operands):
        findings.append(Finding(
            "kernels", "KRN000", site,
            f"{len(spec.in_specs)} in_specs for {len(spec.operands)} "
            "blocked operands — spec/operand mismatch"))

    # -- divisibility + index-map bounds
    prefetch = _worst_case_prefetch(spec, table_fill)
    for k, (bspec, aval, way) in enumerate(pairs):
        block = tuple(bspec.block_shape)
        shape = tuple(aval.shape)
        if len(block) != len(shape):
            findings.append(Finding(
                "kernels", "KRN001", f"{site}/{way}{k}",
                f"block rank {len(block)} != operand rank {len(shape)} "
                f"({block} vs {shape})"))
            continue
        for d, (b, s) in enumerate(zip(block, shape)):
            if b is None:
                continue
            if b > s or s % b:
                findings.append(Finding(
                    "kernels", "KRN002", f"{site}/{way}{k}",
                    f"block dim {d} = {b} does not tile operand dim "
                    f"{s} exactly ({block} vs {shape})"))
        for corner in _grid_corners(spec.grid):
            try:
                idx = bspec.index_map(*corner, *prefetch)
            except Exception as e:   # index map must be total on the grid
                findings.append(Finding(
                    "kernels", "KRN003", f"{site}/{way}{k}",
                    f"index map raised at grid point {corner}: {e!r}"))
                break
            idx = tuple(np.asarray(i).max() for i in jnp.asarray(idx)
                        ) if not isinstance(idx, tuple) else tuple(
                        int(np.asarray(i).max()) for i in idx)
            for d, (i, b, s) in enumerate(zip(idx, block, shape)):
                if b is None:
                    b = 1
                if i < 0 or (i + 1) * b > s:
                    findings.append(Finding(
                        "kernels", "KRN004", f"{site}/{way}{k}",
                        f"index map at grid {corner} selects block {i} "
                        f"on dim {d}: ({i}+1)×{b} > {s} — out of "
                        "bounds under worst-case prefetch values"))
            if len(idx) != len(block):
                findings.append(Finding(
                    "kernels", "KRN005", f"{site}/{way}{k}",
                    f"index map returns {len(idx)} indices for rank-"
                    f"{len(block)} blocks"))

    # -- VMEM budget: double-buffered tiles + scratch
    def block_bytes(bspec, aval):
        n = 1
        for b, s in zip(bspec.block_shape, aval.shape):
            n *= s if b is None else b
        return n * np.dtype(aval.dtype).itemsize

    tile = sum(block_bytes(bs_, av) for bs_, av, _ in pairs
               if len(bs_.block_shape) == len(av.shape))
    scratch = 0
    for sc in spec.scratch_shapes:
        n = 1
        for d in sc.shape:
            n *= d
        scratch += n * np.dtype(sc.dtype).itemsize
        if np.dtype(sc.dtype) != np.float32:
            findings.append(Finding(
                "kernels", "KRN006", site,
                f"scratch accumulator dtype {np.dtype(sc.dtype).name} — "
                "online-softmax / state carries must accumulate in f32"))
    total = 2 * tile + scratch
    if total > VMEM_BYTES:
        findings.append(Finding(
            "kernels", "KRN007", site,
            f"estimated VMEM {total / 2**20:.1f} MiB (2×{tile} tile + "
            f"{scratch} scratch) exceeds the {VMEM_BYTES // 2**20} MiB "
            "per-core budget"))

    # -- int8 dequant contract
    int8_ops = [i for i, av in enumerate(spec.operands)
                if np.dtype(av.dtype) == np.int8]
    if int8_ops:
        scales = [av for av in spec.operands
                  if np.dtype(av.dtype) == np.float32
                  and len(av.shape) == len(
                      spec.operands[int8_ops[0]].shape) - 1]
        if int8_scales_expected and not scales:
            findings.append(Finding(
                "kernels", "KRN008", site,
                "int8 page operands without matching f32 scale "
                "operands — dequantisation cannot be exact"))
    return findings


# ---------------------------------------------------------------------------
# registry: how to invoke each kernel wrapper with representative shapes
# ---------------------------------------------------------------------------
def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _invoke(name: str, fn: Callable, args: tuple,
            static: dict) -> tuple[list[KernelSpec], list[Finding]]:
    """Trace ``fn`` (unwrapped from jax.jit) under eval_shape with
    pallas_call captured."""
    sink: list[KernelSpec] = []
    inner = getattr(fn, "__wrapped__", fn)
    try:
        with capture_pallas(sink, name):
            jax.eval_shape(functools.partial(inner, **static), *args)
    except Exception as e:
        return sink, [Finding(
            "kernels", "KRN009", f"kernels/{name}",
            f"kernel wrapper failed to trace abstractly: {e!r}")]
    if not sink:
        return sink, [Finding(
            "kernels", "KRN010", f"kernels/{name}",
            "no pallas_call reached — wrapper short-circuited, the "
            "kernel is dead code for these shapes")]
    return sink, []


def run() -> list[Finding]:
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.mla_decode import mla_decode_ctx
    from repro.kernels.paged_attention import (paged_decode_attention,
                                               paged_decode_attention_int8)
    from repro.kernels.ssd_scan import ssd_scan

    findings: list[Finding] = []
    P, bs, nblk = 9, 16, 4          # 8 live pages + scratch

    cases: list[tuple[str, Any, tuple, dict, dict, bool]] = [
        # (name, fn, args, static kwargs, table_fill, int8)
        ("flash_attention", flash_attention,
         (_f32(1, 256, 4, 128), _f32(1, 256, 2, 128), _f32(1, 256, 2, 128)),
         dict(causal=True, window=0, softcap=0.0,
              block_q=128, block_k=128, interpret=False), {}, False),
        ("paged_decode_attention", paged_decode_attention,
         (_f32(2, 4, 128),
          _f32(P, bs, 2, 128), _f32(P, bs, 2, 128),
          _i32(2, nblk), _i32(2)),
         dict(softcap=0.0, interpret=False), {0: P - 1}, False),
        ("paged_decode_attention_int8", paged_decode_attention_int8,
         (_f32(2, 4, 128),
          jax.ShapeDtypeStruct((P, bs, 2, 128), jnp.int8),
          jax.ShapeDtypeStruct((P, bs, 2, 128), jnp.int8),
          _f32(P, bs, 2), _f32(P, bs, 2),
          _i32(2, nblk), _i32(2)),
         dict(softcap=0.0, interpret=False), {0: P - 1}, True),
        ("mla_decode_ctx", mla_decode_ctx,
         (_f32(2, 4, 256), _f32(2, 4, 64), _f32(2, 1024, 256),
          _f32(2, 1024, 64),
          jax.ShapeDtypeStruct((2, 1024), jnp.bool_)),
         dict(scale=0.0625, block_s=512, interpret=False), {}, False),
        ("ssd_scan", ssd_scan,
         (_f32(1, 128, 4, 64), _f32(1, 128, 4), _f32(4),
          _f32(1, 128, 2, 64), _f32(1, 128, 2, 64), _f32(4)),
         dict(chunk=64, interpret=False), {}, False),
    ]
    for name, fn, args, static, fill, int8 in cases:
        specs, errs = _invoke(name, fn, args, static)
        findings += errs
        for spec in specs:
            findings += check_spec(spec, table_fill=fill,
                                   int8_scales_expected=int8)
    return findings
