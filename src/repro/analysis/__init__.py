"""Static invariant suite for the serving stack.

``python -m repro.analysis --all`` runs every analyzer and exits
nonzero on any finding; ``--report out.json`` writes the machine-
readable report (see report.Report.to_json). Individual analyzers run
with ``--only <name>``. The suite is wired into tier-1
(tests/test_analysis.py) and CI's ``analysis`` lane.

Analyzers (all static — nothing dispatches on a device):

* ``donation``     — every hot jit's donated operand really aliases
* ``host-sync``    — one device→host transfer per decode chunk
* ``compile-keys`` — the jit-cache key set stays bounded
* ``kernels``      — Pallas block shapes / index maps / VMEM budgets
* ``concurrency``  — class attrs written from two threads
* ``wire``         — pre-affinity import closure + pipe picklability
"""
from repro.analysis.report import Finding, Report

__all__ = ["Finding", "Report", "ANALYZERS", "run_analyzers"]

# name -> import path of a module exposing run() -> list[Finding]
ANALYZERS = {
    "donation": "repro.analysis.donation",
    "host-sync": "repro.analysis.host_sync",
    "compile-keys": "repro.analysis.compile_keys",
    "kernels": "repro.analysis.kernels",
    "concurrency": "repro.analysis.concurrency",
    "wire": "repro.analysis.wire",
}


def run_analyzers(names=None) -> Report:
    """Run the named analyzers (default: all) into one Report. An
    analyzer that crashes is itself a finding — the suite must not
    silently skip a broken gate."""
    import importlib
    import traceback

    report = Report()
    for name in (names or ANALYZERS):
        if name not in ANALYZERS:
            raise KeyError(f"unknown analyzer {name!r}; "
                           f"one of {sorted(ANALYZERS)}")
        try:
            findings = importlib.import_module(ANALYZERS[name]).run()
        except Exception:
            findings = [Finding(
                name, "ERR000", "analyzer",
                "analyzer crashed:\n" + traceback.format_exc())]
        report.analyzers_run.append(name)
        report.extend(findings)
    return report
