"""Host-sync auditor: one device→host transfer per decode chunk, enforced.

The macro-step contract (engine.py): ``step()`` pays exactly ONE host
transfer per fused chunk — the ``jax.device_get((block, emitted))`` in
``_decode_chunk``. Everything else reachable from ``step()`` must stay
on the host or on the device; a stray ``np.asarray(jnp...)``, ``.item()``
or ``block_until_ready()`` in that call graph serialises the pipeline
once per step and silently erodes the divide-and-save win.

Static AST walk, no execution: build the ``self.*()`` call graph from
``ServingEngine.step``, follow ``self.cache_backend.*()`` edges into
both cache backends (serving/cache.py), and count syntactic sync sites
per method against a small allowance table:

* ``_decode_chunk``    — exactly 1 ``jax.device_get`` (the contract)
* ``_pick``            — 2 ``np.asarray(<device expr>)`` sites (greedy /
                         sampled branch; runs once per admission
                         dispatch, not per chunk)
* ``_decode_token``    — exempt: the per-token baseline exists to be
                         measurably worse (benchmarks)

Sync sites recognised: ``jax.device_get(..)``, ``X.block_until_ready()``,
``X.item()``, ``np.asarray(E)`` / ``np.array(E)`` / ``float(E)`` /
``int(E)`` where ``E`` contains a ``jnp.*`` / ``jax.*`` call (a device
value forced to host). ``jnp.asarray`` is host→device and free.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.report import Finding, line_suppressed

_SERVING = pathlib.Path(__file__).resolve().parents[1] / "serving"

# method -> {sync kind -> allowed count}; None = exempt entirely
ALLOWANCES: dict[str, dict[str, int] | None] = {
    "_decode_chunk": {"device_get": 1},
    "_pick": {"host_coerce": 2},
    "_decode_token": None,
}

ENTRY = "step"


def _is_name_chain(node: ast.AST, *chain: str) -> bool:
    """True when ``node`` is exactly ``chain[0].chain[1]...``."""
    for part in reversed(chain[1:]):
        if not (isinstance(node, ast.Attribute) and node.attr == part):
            return False
        node = node.value
    return isinstance(node, ast.Name) and node.id == chain[0]


def _contains_device_call(node: ast.AST) -> bool:
    """Does the expression contain a call on ``jnp.*`` / ``jax.*``?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            f = sub.func
            while isinstance(f, ast.Attribute):
                f = f.value
            if isinstance(f, ast.Name) and f.id in ("jnp", "jax"):
                return True
    return False


def _sync_kind(call: ast.Call) -> str | None:
    f = call.func
    if _is_name_chain(f, "jax", "device_get"):
        return "device_get"
    if isinstance(f, ast.Attribute) and f.attr in ("block_until_ready",
                                                   "item"):
        return "block"
    if call.args and (
            _is_name_chain(f, "np", "asarray")
            or _is_name_chain(f, "np", "array")
            or (isinstance(f, ast.Name) and f.id in ("float", "int"))):
        if _contains_device_call(call.args[0]):
            return "host_coerce"
    return None


class _ClassIndex:
    """Methods of one class: sync sites + intra/inter-class call edges."""

    def __init__(self, cls: ast.ClassDef, path: pathlib.Path,
                 lines: list[str]):
        self.path = path
        self.lines = lines
        self.methods: dict[str, ast.FunctionDef] = {
            n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}

    def calls(self, meth: str) -> tuple[set[str], set[str]]:
        """(self.X() targets, self.cache_backend.X() targets)."""
        own: set[str] = set()
        backend: set[str] = set()
        fn = self.methods.get(meth)
        if fn is None:
            return own, backend
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute):
                if _is_name_chain(f.value, "self"):
                    own.add(f.attr)
                elif _is_name_chain(f.value, "self", "cache_backend"):
                    backend.add(f.attr)
        return own, backend

    def sync_sites(self, meth: str) -> list[tuple[str, int]]:
        fn = self.methods.get(meth)
        if fn is None:
            return []
        out = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                kind = _sync_kind(node)
                if kind and not line_suppressed(self.lines, node.lineno,
                                                "host-sync"):
                    out.append((kind, node.lineno))
        return out


def _load(path: pathlib.Path) -> dict[str, _ClassIndex]:
    src = path.read_text()
    tree = ast.parse(src)
    lines = src.splitlines()
    return {n.name: _ClassIndex(n, path, lines)
            for n in tree.body if isinstance(n, ast.ClassDef)}


def run(engine_path: pathlib.Path | None = None,
        cache_path: pathlib.Path | None = None) -> list[Finding]:
    engine_path = engine_path or _SERVING / "engine.py"
    cache_path = cache_path or _SERVING / "cache.py"
    eng_classes = _load(engine_path)
    cache_classes = _load(cache_path)
    engine = eng_classes.get("ServingEngine")
    if engine is None:
        return [Finding("host-sync", "SYN000", str(engine_path),
                        "ServingEngine class not found — auditor is "
                        "looking at the wrong module")]
    backends = [c for n, c in cache_classes.items()
                if n in ("DenseCache", "PagedCache")]

    # reachability from step() across self.*() edges; cache_backend.*()
    # edges fan out into both backend classes
    seen: set[tuple[int, str]] = set()
    work: list[tuple[_ClassIndex, str]] = [(engine, ENTRY)]
    findings: list[Finding] = []
    while work:
        idx, meth = work.pop()
        key = (id(idx), meth)
        if key in seen or meth not in idx.methods:
            continue
        seen.add(key)
        allow = ALLOWANCES.get(meth, {})
        if allow is None:          # exempt (per-token baseline)
            continue
        counts: dict[str, int] = {}
        for kind, lineno in idx.sync_sites(meth):
            counts[kind] = counts.get(kind, 0) + 1
            if counts[kind] > allow.get(kind, 0):
                findings.append(Finding(
                    "host-sync", "SYN001",
                    f"{idx.path.name}:{lineno}",
                    f"device→host sync ({kind}) in {meth}() reachable "
                    "from step() beyond the one-transfer-per-chunk "
                    "contract"))
        own, backend = idx.calls(meth)
        for m in own:
            work.append((idx, m))
        for m in backend:
            for b in backends:
                work.append((b, m))
    return findings
