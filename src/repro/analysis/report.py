"""Finding / Report data model for the static-analysis suite.

Every analyzer returns a list of ``Finding``s; the CLI aggregates them
into a ``Report`` with a stable machine-readable JSON shape (consumed by
the CI ``analysis`` lane, which archives it as an artifact and fails the
build when ``errors`` is nonzero).

Suppression: a finding anchored to a source line is dropped when that
line (or the line above it) carries ``# analysis: allow(<analyzer>)``.
Non-source findings (donation / kernel audits) can be waived with the
CLI's ``--suppress CODE`` flag; both mechanisms are deliberate, visible
markers rather than config-file state.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterable

_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(([\w\-,\s]*)\)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant.

    ``analyzer`` names the check group (``donation``, ``host-sync``,
    ``compile-keys``, ``kernels``, ``concurrency``, ``wire``); ``code``
    is a stable short id for suppression; ``location`` is either
    ``path:line`` or a logical site like ``qwen3-0.6b/paged/chunk``.
    """
    analyzer: str
    code: str
    location: str
    message: str
    severity: str = "error"          # "error" fails the build; "warning"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (f"[{self.analyzer}] {self.code} {self.severity}: "
                f"{self.location}: {self.message}")


@dataclasses.dataclass
class Report:
    findings: list[Finding] = dataclasses.field(default_factory=list)
    analyzers_run: list[str] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps({
            "schema": 1,
            "analyzers_run": self.analyzers_run,
            "counts": {
                "errors": len(self.errors),
                "warnings": len(self.findings) - len(self.errors),
            },
            "findings": [f.to_dict() for f in self.findings],
        }, indent=indent)


def line_suppressed(source_lines: list[str], lineno: int,
                    analyzer: str) -> bool:
    """True when line ``lineno`` (1-based) — or the line directly above
    it — carries ``# analysis: allow(<analyzer>)`` (or ``allow()`` for
    any analyzer)."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(source_lines):
            m = _ALLOW_RE.search(source_lines[ln - 1])
            if m:
                names = {s.strip() for s in m.group(1).split(",") if s.strip()}
                if not names or analyzer in names:
                    return True
    return False


def apply_suppressions(findings: list[Finding],
                       codes: Iterable[str]) -> list[Finding]:
    """Drop findings whose ``code`` is in ``codes`` (CLI --suppress)."""
    codes = set(codes)
    return [f for f in findings if f.code not in codes]
