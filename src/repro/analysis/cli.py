"""``python -m repro.analysis`` — run the static invariant suite.

Exit status 0 when no error-severity finding survives suppression,
1 otherwise, 2 on usage errors. ``--report`` always writes the JSON
report (including on a clean run) so CI can archive it either way.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from repro.analysis import ANALYZERS, run_analyzers
from repro.analysis.report import apply_suppressions


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant suite (donation / host-sync / "
                    "compile-keys / kernels / concurrency / wire).")
    ap.add_argument("--all", action="store_true",
                    help="run every analyzer (default when --only absent)")
    ap.add_argument("--only", action="append", default=[],
                    metavar="NAME", help="run one analyzer (repeatable); "
                    f"names: {', '.join(sorted(ANALYZERS))}")
    ap.add_argument("--report", metavar="PATH",
                    help="write the JSON report here")
    ap.add_argument("--suppress", action="append", default=[],
                    metavar="CODE",
                    help="drop findings with this code (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list analyzers and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, mod in sorted(ANALYZERS.items()):
            print(f"{name:14s} {mod}")
        return 0
    names = args.only or None
    if args.all and args.only:
        ap.error("--all and --only are mutually exclusive")
    try:
        report = run_analyzers(names)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2
    if args.suppress:
        report.findings = apply_suppressions(report.findings,
                                             args.suppress)
    if args.report:
        pathlib.Path(args.report).write_text(report.to_json() + "\n")
    for f in report.findings:
        print(f)
    n = len(report.errors)
    print(f"{', '.join(report.analyzers_run)}: "
          f"{n} error(s), {len(report.findings) - n} warning(s)")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
