"""AdamW + cosine schedule with warmup, pure-pytree (no optax)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: dict) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.betas
    lr = schedule(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
