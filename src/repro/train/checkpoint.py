"""Flat-npz pytree checkpointing with metadata sidecar."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

_SEP = "::"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path if path.endswith(".npz") else path + ".npz")
    final = path if path.endswith(".npz") else path + ".npz"
    with open(final + ".meta.json", "w") as f:
        json.dump(meta or {}, f, indent=2, default=str)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure (and dtypes) of ``like``."""
    final = path if path.endswith(".npz") else path + ".npz"
    with np.load(final) as data:
        flat = dict(data)
    paths, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(tdef, leaves)


def load_meta(path: str) -> dict:
    final = path if path.endswith(".npz") else path + ".npz"
    with open(final + ".meta.json") as f:
        return json.load(f)
