"""Training step factory + loop: grad, clip, AdamW, optional remat and
gradient accumulation (microbatch scan)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import AdamWConfig, apply_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    remat: bool = False
    remat_policy: str = "none"   # "none" | "collectives" (save block outs)
    microbatches: int = 1     # grad accumulation factor


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Pure; jit/pjit it with the shardings of your mesh."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=tcfg.remat,
                                   remat_policy=tcfg.remat_policy)
        return loss, metrics

    def single(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            def split(x):
                return x.reshape(tcfg.microbatches,
                                 x.shape[0] // tcfg.microbatches, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss, metrics, grads = single(params, mb)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, grads),
                        acc_l + loss), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = loss_sum / tcfg.microbatches
            metrics = {}
        else:
            loss, metrics, grads = single(params, batch)
        params, opt_state, opt_metrics = apply_update(
            tcfg.opt, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def train(model: Model, tcfg: TrainConfig, batches, n_steps: int,
          params=None, key=None, log_every: int = 10,
          logger: Callable[[int, dict], None] | None = None):
    """Single-host CPU training driver (examples/tests). Returns
    (params, opt_state, history)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    params = params if params is not None else model.init(key)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, tcfg))
    history = []
    t0 = time.time()
    for step, batch in enumerate(batches):
        if step >= n_steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if jnp.ndim(v) == 0}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            history.append(m)
            if logger:
                logger(step, m)
    return params, opt_state, history
