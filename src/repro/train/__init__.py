from repro.train.loop import TrainConfig, make_train_step, train
from repro.train.optimizer import AdamWConfig, apply_update, init_opt_state

__all__ = ["TrainConfig", "make_train_step", "train", "AdamWConfig",
           "apply_update", "init_opt_state"]
