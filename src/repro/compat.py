"""Shims for jax APIs that moved between releases.

The model/serving code targets the current public surface
(``jax.sharding.get_abstract_mesh`` / ``jax.set_mesh``); on the 0.4.x
series those only exist under ``jax._src.mesh``. Centralising the fallback
here keeps version probes out of the hot paths and gives every caller the
same contract: ``get_abstract_mesh()`` always returns a mesh object with
``axis_names`` / ``axis_sizes`` (empty when no mesh is ambient), and
``set_mesh(mesh)`` is a context manager installing a concrete mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import AbstractMesh

if hasattr(jax.sharding, "get_abstract_mesh"):
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:
    from jax._src import mesh as _mesh_src

    _EMPTY_MESH = AbstractMesh(())

    def get_abstract_mesh() -> AbstractMesh:
        mesh = _mesh_src.get_abstract_mesh()
        # unset ambient mesh is a bare () on 0.4.x
        return mesh if hasattr(mesh, "axis_names") else _EMPTY_MESH

if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    import contextlib

    @contextlib.contextmanager
    def set_mesh(mesh):
        """0.4.x fallback: the internal ``set_mesh`` force-enables the
        experimental sharding-in-types mode (which lacks rules for gather
        et al.), so install only the resource env + abstract mesh."""
        from jax._src.mesh import set_abstract_mesh
        with mesh, set_abstract_mesh(mesh.abstract_mesh):
            yield


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh=None, in_specs, out_specs):
        """New-style ``jax.shard_map`` (ambient-mesh, keyword specs) on top
        of the 0.4.x experimental API. ``check_rep`` is off: the kernels
        here merge partial stats themselves, and the old checker rejects
        some of the collectives they use."""
        if mesh is None:
            mesh = get_abstract_mesh()
        return _shard_map_old(f, mesh, in_specs, out_specs, check_rep=False)


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` with Auto axis types where the API supports them
    (newer jax requires them for the sharding-in-types dry-run path)."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
            **kwargs)
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
