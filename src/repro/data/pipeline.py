"""Deterministic synthetic data pipelines.

Two streams:
  * ``LmTokenStream`` — seeded synthetic LM batches (zipf-ish marginals so
    the loss curve is non-trivial), the training substrate.
  * ``VideoRequestStream`` — the paper's workload: a "video" whose frames
    are independent inference units. Used by the splitter benchmarks and
    the serving example; frames are synthetic feature maps / token prompts.

Everything is reproducible from (seed, index) — no files, no global state —
and shardable: ``LmTokenStream.batches`` yields numpy arrays the launcher
places onto the mesh with NamedSharding.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class LmTokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        # zipf-ish marginal over the vocab; markov-ish repeats so that a
        # model can actually reduce loss below ln(V)
        base = rng.zipf(1.3, size=(self.batch_size, self.seq_len))
        tokens = np.minimum(base - 1, self.vocab_size - 1).astype(np.int32)
        # inject copy structure: second half repeats first half shifted
        half = self.seq_len // 2
        tokens[:, half:half * 2] = tokens[:, :half]
        return {"tokens": tokens}

    def batches(self, start: int = 0) -> Iterator[dict]:
        step = start
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class VideoRequestStream:
    """A video = n_frames independent units (paper: 30 s of video)."""

    n_frames: int = 900           # 30 s @ 30 fps
    frame_shape: tuple = (128, 128, 3)
    seed: int = 0

    def frames(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.standard_normal(
            (self.n_frames, *self.frame_shape), dtype=np.float32)

    def prompt_requests(self, vocab_size: int, prompt_len: int = 64
                        ) -> list[np.ndarray]:
        """The LLM-serving analogue: independent prompt requests."""
        rng = np.random.default_rng(self.seed)
        return [rng.integers(0, vocab_size, size=(prompt_len,),
                             dtype=np.int32)
                for _ in range(self.n_frames)]
