from repro.data.pipeline import LmTokenStream, VideoRequestStream

__all__ = ["LmTokenStream", "VideoRequestStream"]
