"""Registry of the 10 assigned architectures (+ the CPU-testbed CNN)."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape, reduce_config

_MODULES = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
    "gemma3-27b": "repro.configs.gemma3_27b",
}

ARCH_NAMES = tuple(_MODULES)

# extras: demonstrably-one-file additions beyond the assigned pool; they
# are selectable everywhere (--arch) but excluded from assigned_pairs()
_EXTRA_MODULES = {
    "llama3.1-8b": "repro.configs.llama31_8b",
}
_MODULES.update(_EXTRA_MODULES)
EXTRA_ARCH_NAMES = tuple(_EXTRA_MODULES)


def get_config(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return reduce_config(get_config(name[: -len("-reduced")]))
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def assigned_pairs() -> list[tuple[str, str]]:
    """All (arch, shape) pairs that must lower (skips per DESIGN.md)."""
    pairs = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            if shape.name == "long_500k" and not cfg.supports_long_decode:
                continue  # documented skip (DESIGN.md §Arch-applicability)
            pairs.append((arch, shape.name))
    return pairs
