from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape, reduce_config
from repro.configs.registry import ARCH_NAMES, assigned_pairs, get_config, get_shape

__all__ = [
    "ARCH_NAMES",
    "ArchConfig",
    "INPUT_SHAPES",
    "InputShape",
    "assigned_pairs",
    "get_config",
    "get_shape",
    "reduce_config",
]
