"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family] — dense, GQA kv=8, qk_norm.

Per the model card head_dim is 128 even though 16*128 != d_model (q/k/v
projections are rectangular); we keep that faithful.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B (0.6B sibling)",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
