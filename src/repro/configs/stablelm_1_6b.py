"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b] — dense MHA, partial rotary."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100_352,
    partial_rotary_factor=0.25,
    norm_type="layernorm",
)
