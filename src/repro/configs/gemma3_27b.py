"""Gemma3-27B [hf:google/gemma-3-1b-pt family] — 5 local : 1 global, 128k ctx."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt (27B sibling)",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    local_global_pattern=5,   # 5 local layers per 1 global layer
    act="gelu",
)
