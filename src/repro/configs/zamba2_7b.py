"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone + weight-shared attention block.

81 Mamba2 layers; a single weight-shared (attention + MLP) block is applied
every 6 SSM layers (per-application LoRA adapters from the model card are
omitted — noted in DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_groups=1,
    shared_attn_every=6,
)
