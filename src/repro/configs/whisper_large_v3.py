"""Whisper-large-v3 [arXiv:2212.04356] — enc-dec; mel+conv frontend stubbed.

input_specs() provides the 1500 post-conv frame embeddings directly
(the mel-spectrogram + conv1d stem is the allowed frontend stub). The
assigned seq_len applies to the decoder; the encoder sees encoder_seq frames.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=32,             # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    n_encoder_layers=32,
    encoder_seq=1500,
    cross_attention=True,
    act="gelu",
    tie_embeddings=True,
    norm_type="layernorm",
    mlp_gated=False,
    pos_embed="learned",
)
