"""Llama-3.1-8B [hf:meta-llama/Llama-3.1-8B] — EXTRA architecture.

Not part of the assigned pool (not in ``assigned_pairs``/the dry-run
tables); included to demonstrate that adding an architecture to the
framework is one config file: dense GQA with a 500k rope theta, nothing
else new.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.1-8b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.1-8B (extra, not assigned)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    rope_theta=500_000.0,
)
