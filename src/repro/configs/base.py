"""Architecture + run configuration dataclasses.

Every assigned architecture gets one module in this package exporting
``CONFIG: ArchConfig`` with the exact assigned hyper-parameters, plus a
``reduced()`` helper returning a CPU-smoke-testable variant of the same
family (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

ArchType = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Static architecture description (model-card faithful)."""

    name: str
    arch_type: ArchType
    source: str  # citation bracket from the assignment

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # ---- attention options ----
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    partial_rotary_factor: float = 1.0
    sliding_window: int = 0          # 0 = full attention
    local_global_pattern: int = 0    # k => k local layers per 1 global layer
    attn_logit_softcap: float = 0.0
    # "model" keeps the cache in the activation dtype; "int8" stores k/v
    # quantised (per-token-per-head absmax scales) and dequantises per tile
    # inside the decode kernel — halves the decode memory-roofline term
    # (§Perf, beyond-paper; the paper's workload is inference-bound too)
    kv_cache_dtype: str = "model"

    # ---- MLA (DeepSeek) ----
    mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MoE ----
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0          # leading dense (non-MoE) layers
    router_aux_loss_coef: float = 0.0
    # capacity factors: train uses GShard-style drops; eval uses a roomier
    # buffer (E/K makes eval provably dropless — used by the reduced
    # test configs so prefill/decode match the full forward exactly)
    moe_train_cf: float = 1.25
    moe_eval_cf: float = 2.0
    # dispatch groups (0/1 = one global dispatch). Set to the data-axis size
    # for shard-local dispatch: the position-in-expert cumsum and the
    # (E, C, d) scatter stay within each data shard, so GSPMD emits an
    # all-to-all at the group boundary instead of all-reducing the whole
    # dispatch buffer per layer (§Perf iteration 1 — 104 GB/layer → ~0).
    moe_dispatch_groups: int = 0

    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # ---- hybrid (Zamba2) ----
    shared_attn_every: int = 0       # apply the weight-shared block every k SSM layers

    # ---- encoder-decoder (Whisper) ----
    n_encoder_layers: int = 0
    encoder_seq: int = 0             # fixed frame count from the (stubbed) frontend
    cross_attention: bool = False

    # ---- VLM (InternVL2) ----
    n_vision_tokens: int = 0
    vision_embed_dim: int = 0        # dim of the stubbed patch embeddings

    # ---- misc ----
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: Literal["silu", "gelu"] = "silu"
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    mlp_gated: bool = True
    pos_embed: Literal["rope", "learned"] = "rope"

    # ------------------------------------------------------------------
    @property
    def is_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_long_decode(self) -> bool:
        """True iff decode memory/compute is sub-linear-enough for 500k ctx.

        SSM/hybrid: O(1) state.  SWA: bounded window cache.  MLA: latent
        cache ~576 B-equivalents/token/layer.  Pure full-attention dense
        archs and the bounded-context audio enc-dec are excluded (see
        DESIGN.md §Arch-applicability).
        """
        if self.arch_type in ("ssm", "hybrid"):
            return True
        if self.arch_type == "audio":
            return False
        if self.sliding_window > 0:
            return True
        if self.mla:
            return True
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch is decoder-bearing

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline cross-checks)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        if self.is_moe and self.n_dense_layers:
            # leading dense layers use the dense FFN width, not the experts
            n += self._dense_layer_params() * self.n_dense_layers
            n += self._decoder_layer_params() * (self.n_layers
                                                 - self.n_dense_layers)
        else:
            n += self._decoder_layer_params() * self.n_layers
        if self.shared_attn_every:
            n += self._shared_block_params()
        if self.n_encoder_layers:
            n += self._encoder_layer_params() * self.n_encoder_layers
        if self.n_vision_tokens:
            n += self.vision_embed_dim * d + d * d    # projector (2 layer)
        n += d                                        # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k routed only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        routed_all = self.n_experts * 3 * d * self.moe_d_ff
        routed_active = self.n_experts_per_tok * 3 * d * self.moe_d_ff
        return self.param_count() - (routed_all - routed_active) * (
            self.n_layers - self.n_dense_layers
        )

    # -- helpers ------------------------------------------------------
    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla:
            qk_head = self.qk_nope_head_dim + self.qk_rope_head_dim
            n = d * self.n_heads * qk_head                       # q proj
            n += d * (self.kv_lora_rank + self.qk_rope_head_dim)  # kv down
            n += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_head_dim + self.v_head_dim)          # kv up
            n += self.n_heads * self.v_head_dim * d               # o proj
            return n
        hd = self.head_dim
        n = d * self.n_heads * hd          # q
        n += 2 * d * self.n_kv_heads * hd  # k, v
        n += self.n_heads * hd * d         # o
        return n

    def _mlp_params(self, d_ff: int) -> int:
        # gate+up+down when gated (SwiGLU); up+down otherwise
        return (3 if self.mlp_gated else 2) * self.d_model * d_ff

    def _decoder_layer_params(self) -> int:
        d = self.d_model
        if self.arch_type in ("ssm", "hybrid"):
            # Mamba2 block: in_proj (x, z, B, C, dt), conv, out_proj, norms
            di, ds, ng = self.d_inner, self.ssm_state, self.ssm_n_groups
            nh = self.ssm_n_heads
            n = d * (2 * di + 2 * ng * ds + nh)   # in_proj
            n += (di + 2 * ng * ds) * self.ssm_conv_width  # conv1d
            n += di * d                            # out_proj
            n += 2 * nh + di + d                   # A_log, D, norm, rmsnorm
            return n
        n = self._attn_params() + 2 * self.d_model  # attn + 2 norms
        if self.cross_attention:
            n += self._attn_params() + self.d_model  # cross-attn + 3rd norm
        if self.is_moe:
            n += d * self.n_experts                               # router
            n += self.n_experts * self._mlp_params(self.moe_d_ff)
            n += self.n_shared_experts * self._mlp_params(self.moe_d_ff)
        else:
            n += self._mlp_params(self.d_ff)
        return n

    def _dense_layer_params(self) -> int:
        return self._attn_params() + self._mlp_params(self.d_ff) \
            + 2 * self.d_model

    def _shared_block_params(self) -> int:
        return self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model

    def _encoder_layer_params(self) -> int:
        # encoder layer: self-attn + mlp; decoder cross-attn params are part
        # of decoder layer count via cross_attention flag
        return self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (workload) input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduce_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A smoke-testable reduced variant of the same architecture family."""
    d = min(cfg.d_model, 256)
    heads = max(2, min(cfg.n_heads, 4))
    kv = heads if cfg.n_kv_heads == cfg.n_heads else max(1, heads // 2)
    base = dict(
        n_layers=2,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=32,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
    )
    if cfg.is_moe:
        ne, nk = min(cfg.n_experts, 4), min(cfg.n_experts_per_tok, 2)
        base.update(
            n_experts=ne,
            n_experts_per_tok=nk,
            moe_d_ff=min(cfg.moe_d_ff, 128),
            n_dense_layers=min(cfg.n_dense_layers, 1),
            moe_eval_cf=ne / nk,  # dropless => decode == forward exactly
        )
    if cfg.is_ssm:
        base.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=16,
                    ssm_chunk=32)
    if cfg.shared_attn_every:
        base.update(shared_attn_every=1, d_ff=min(cfg.d_ff, 512))
    if cfg.n_encoder_layers:
        base.update(n_encoder_layers=2, encoder_seq=16)
    if cfg.n_vision_tokens:
        base.update(n_vision_tokens=8, vision_embed_dim=64)
    if cfg.mla:
        base.update(kv_lora_rank=64, qk_rope_head_dim=16, qk_nope_head_dim=32,
                    v_head_dim=32, head_dim=48)
    if cfg.sliding_window:
        base.update(sliding_window=min(cfg.sliding_window, 64))
    if cfg.local_global_pattern:
        # 1 local + 1 global per super-block so 2 layers exercise the
        # scanned super-block path (n_super=1) instead of leaving it empty
        base.update(local_global_pattern=1)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **base)
