"""Mixtral-8x22B [arXiv:2401.04088] — 8 experts top-2, SWA (per assignment)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    source="arXiv:2401.04088",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,            # kept for reference; MoE layers use moe_d_ff
    vocab_size=32_768,
    sliding_window=4096,   # assignment bracket lists SWA
    n_experts=8,
    n_experts_per_tok=2,
    moe_d_ff=16384,
    router_aux_loss_coef=0.01,
    moe_dispatch_groups=16,  # shard-local dispatch (§Perf iter 1/4)
    rope_theta=1_000_000.0,
)
