"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    source="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,       # -> 80 SSD heads
    ssm_n_groups=1,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
