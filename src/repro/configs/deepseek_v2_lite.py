"""DeepSeek-V2-Lite-16B [arXiv:2405.04434] — MLA kv_lora=512, 64 routed top-6 + 2 shared.

The assignment bracket mentions "160 routed", which is DeepSeek-V2-full's
expert count; the 64e/top-6 figures in the same bracket are the Lite ones
and are what we build (noted in DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    source="arXiv:2405.04434",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,           # qk_nope 128 + rope 64
    d_ff=10944,             # first (dense) layer FFN
    vocab_size=102_400,
    mla=True,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    n_experts=64,
    n_experts_per_tok=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    n_dense_layers=1,
    router_aux_loss_coef=0.003,
    moe_dispatch_groups=16,  # shard-local dispatch (§Perf iter 1/4)
)
