"""InternVL2-26B [arXiv:2404.16821] — InternViT (stubbed) + InternLM2-20B backbone.

The vision frontend is the one allowed stub: input_specs() provides
precomputed patch embeddings (n_vision_tokens, vision_embed_dim) which a
2-layer projector maps into the LM embedding space.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92_553,
    n_vision_tokens=256,
    vision_embed_dim=3200,   # InternViT-6B width
    rope_theta=1_000_000.0,
)
